"""Mapping-compiler sweep — allocator policy x engine.

Three views of the `repro.mapping` subsystem:

* **Modeled**: compile qwen1.5-0.5b (the LM serving target) and CNN-M
  (a ragged paper workload) into MappingPlans across policy x tile spec
  x tile budget, and price each through ``costmodel.price_plan``. The
  budget axis shows what the planner exists for: shrinking the physical
  tile pool below the block count forces co-residency, and the plan's
  ``steps_per_vector`` serialization surfaces directly in latency;
  ``balance_ratio`` shows greedy's load-balancing win on ragged blocks.
* **Measured**: the plan-driven ``tiled`` engine executes a binarized
  matmul under every policy and must be bit-exact against every other
  registered backend (the sweep fails otherwise) — placement permutes
  tile order, never the math. The candidate axis is a
  :class:`repro.compiler.HardwareTarget` per (engine | tiled x policy),
  resolved through the same backend resolution ``compile()`` runs.
* **Serving**: a smoke LM compiled onto a
  ``HardwareTarget(engine="tiled", mapping_policy="greedy")`` and
  served through ``compile(...).serve(...)`` must generate
  byte-identically to the reference target (plan-driven execution is
  semantically invisible, like every other backend).

``run(smoke)`` returns the rows as JSON-ready data for
``benchmarks/run.py --out``.
"""

from __future__ import annotations

import time


def modeled_sweep(smoke: bool) -> list[dict]:
    from repro.configs import get_config
    from repro.core import costmodel
    from repro.core.crossbar import EPCM_TILE, OPCM_TILE
    from repro.core.networks import NETWORKS
    from repro.mapping import POLICIES, allocate, balance_ratio, required_tiles

    workloads = [("qwen1.5-0.5b", get_config("qwen1.5-0.5b"))]
    if not smoke:
        workloads.append(("CNN-M", NETWORKS["CNN-M"]))

    rows = []
    for wl_name, wl in workloads:
        for spec_name, spec in (("ePCM", EPCM_TILE), ("oPCM", OPCM_TILE)):
            need = required_tiles(wl, spec)
            budgets = [None, 64] if smoke else [None, max(1, need // 2), 64]
            for policy in POLICIES:
                for budget in budgets:
                    plan = allocate(wl, spec=spec, policy=policy, tile_budget=budget)
                    cost = costmodel.price_plan(plan)
                    rows.append({
                        "workload": wl_name,
                        "spec": spec_name,
                        "policy": policy,
                        "tile_budget": budget,
                        "n_tiles": plan.n_tiles,
                        "n_blocks": plan.n_blocks,
                        "utilization": round(plan.utilization(), 4),
                        "balance": round(balance_ratio(plan), 4),
                        "k": plan.preferred_group_size(),
                        "binary_steps": cost.binary_steps,
                        "latency_us": cost.latency_s * 1e6,
                        "energy_uj": cost.energy_j * 1e6,
                        "design": cost.design,
                    })
    return rows


def measured_sweep(smoke: bool) -> tuple[list[dict], bool]:
    import numpy as np

    from repro import compiler as compiler_lib
    from repro.compiler import HardwareTarget
    from repro.core import engine as engine_lib
    from repro.mapping import POLICIES

    b, m, n = (8, 100, 30) if smoke else (32, 513, 129)
    rng = np.random.default_rng(0)
    a = rng.choice(np.array([-1.0, 1.0], np.float32), size=(b, m))
    w = rng.choice(np.array([-1.0, 1.0], np.float32), size=(m, n))
    ref = np.asarray(engine_lib.get_engine("reference").binary_vmm(a, w)).astype(np.int64)

    baselines = ("reference", "tacitmap", "wdm") if smoke else tuple(
        e for e in engine_lib.list_engines() if e != "tiled"
    )
    # the candidate axis is a HardwareTarget per (engine | tiled x
    # policy); resolve_engine is the same backend resolution compile()
    # runs (reference resolves to the plain-jnp path -> the engine)
    grid = [(name, "-", HardwareTarget(engine=name)) for name in baselines]
    grid += [
        ("tiled", policy, HardwareTarget(engine="tiled", mapping_policy=policy))
        for policy in POLICIES
    ]
    candidates = [
        (name, policy,
         compiler_lib.resolve_engine(t) or engine_lib.get_engine("reference"))
        for name, policy, t in grid
    ]

    rows, exact = [], True
    for name, policy, eng in candidates:
        t0 = time.perf_counter()
        got = np.asarray(eng.binary_vmm(a, w)).astype(np.int64)
        wall_ms = (time.perf_counter() - t0) * 1e3
        ok = bool(np.array_equal(got, ref))
        exact &= ok
        rows.append({
            "engine": name,
            "policy": policy,
            "exact": ok,
            "steps": eng.steps_for(m, n, b),
            "wall_ms": wall_ms,
        })
    return rows, exact


def serving_roundtrip(smoke: bool) -> tuple[dict, bool]:
    import dataclasses

    import jax
    import numpy as np

    from repro import compiler as compiler_lib
    from repro.compiler import HardwareTarget
    from repro.configs import get_smoke_config
    from repro.models import lm as lm_lib
    from repro.serving import Request

    cfg = dataclasses.replace(get_smoke_config("qwen1.5-0.5b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    n_req, gen = (2, 2) if smoke else (4, 4)
    prompts = [rng.integers(1, cfg.vocab_size, (6,), dtype=np.int32) for _ in range(n_req)]

    def generations(target: HardwareTarget):
        compiled = compiler_lib.compile(cfg, params, target)
        se = compiled.serve(max_batch=2, max_len=16)
        for i, p in enumerate(prompts):
            se.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
        return {r.rid: tuple(r.generated) for r in se.run_to_completion()}, compiled

    # the one-call pipeline compiles the greedy plan itself
    tiled, compiled = generations(
        HardwareTarget(engine="tiled", mapping_policy="greedy")
    )
    ref, _ = generations(HardwareTarget())
    exact = tiled == ref
    plan = compiled.plan
    return {
        "plan_tiles": plan.n_tiles,
        "plan_k": plan.preferred_group_size(),
        "requests": n_req,
        "exact_vs_reference": exact,
    }, exact


def run(smoke: bool = False) -> tuple[int, dict]:
    modeled = modeled_sweep(smoke)
    measured, m_exact = measured_sweep(smoke)
    serving, s_exact = serving_roundtrip(smoke)

    print("\n== mapping plans, modeled (policy x spec x tile budget) ==")
    print(f"{'workload':>13s} {'spec':>5s} {'policy':>13s} {'budget':>7s} "
          f"{'tiles':>6s} {'util':>5s} {'bal':>5s} {'K':>3s} {'steps':>7s} "
          f"{'lat_us':>8s} {'en_uJ':>8s}")
    for r in modeled:
        budget = "-" if r["tile_budget"] is None else str(r["tile_budget"])
        print(f"{r['workload']:>13s} {r['spec']:>5s} {r['policy']:>13s} {budget:>7s} "
              f"{r['n_tiles']:6d} {r['utilization']:5.2f} {r['balance']:5.2f} "
              f"{r['k']:3d} {r['binary_steps']:7d} {r['latency_us']:8.2f} "
              f"{r['energy_uj']:8.3f}")
    print("(budget < blocks => co-resident blocks serialize: steps/latency grow; "
          "the allocator policy decides how gracefully)")

    print("\n== tiled engine, measured (policy x engine bit-exactness) ==")
    print(f"{'engine':>14s} {'policy':>13s} {'exact':>6s} {'steps':>6s} {'wall_ms':>8s}")
    for r in measured:
        print(f"{r['engine']:>14s} {r['policy']:>13s} {str(r['exact']):>6s} "
              f"{r['steps']:6d} {r['wall_ms']:8.1f}")

    print(f"\nserving round-trip (qwen smoke, engine=tiled + compiled plan): "
          f"exact_vs_reference={serving['exact_vs_reference']} "
          f"(plan: {serving['plan_tiles']} tiles, K={serving['plan_k']})")

    ok = m_exact and s_exact
    payload = {"modeled": modeled, "measured": measured, "serving": serving, "ok": ok}
    return (0 if ok else 1), payload


def main(smoke: bool = False) -> int:
    rc, _ = run(smoke)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
