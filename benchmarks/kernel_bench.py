"""Kernel-level benchmark: the TPU-native TacitMap (packed XNOR matmul)
and WDM MMM kernels vs their dense references.

On this CPU container the Pallas kernels run in interpret mode, so wall
time is NOT the metric — the reported quantities are:

  * correctness (allclose vs ref, also covered by tests/)
  * analytic HBM traffic: packed int32 weights move 16x fewer bytes
    than bf16 (32x vs fp32) — the memory-roofline translation of the
    paper's "1 bit per oPCM cell" (DESIGN.md §3)
  * wall time of the *jnp* packed path vs dense matmul on CPU, as a
    directional sanity check only.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as engine_lib
from repro.kernels import ops, ref


def _time(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(m=512, k=1024, n=512, seed=0) -> dict:
    key = jax.random.key(seed)
    ka, kw = jax.random.split(key)
    a = jnp.sign(jax.random.normal(ka, (m, k))) .astype(jnp.float32)
    w = jnp.sign(jax.random.normal(kw, (k, n))).astype(jnp.float32)

    dense = jax.jit(lambda a, w: a.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16))
    packed = jax.jit(lambda a, w: ops.xnor_matmul(a, w))

    out_ref = np.asarray(ref.xnor_matmul_ref(a, w))
    out_pk = np.asarray(packed(a, w))
    ok = np.array_equal(out_ref, out_pk)

    t_dense = _time(dense, a, w)
    t_packed = _time(packed, a, w)

    bytes_bf16 = (m * k + k * n) * 2
    bytes_packed = (m * k + k * n) / 8  # 1 bit per weight/activation
    return {
        "shape": (m, k, n),
        "bitexact": bool(ok),
        "cpu_t_dense_s": t_dense,
        "cpu_t_packed_s": t_packed,
        "hbm_bytes_bf16": bytes_bf16,
        "hbm_bytes_packed": bytes_packed,
        "traffic_reduction": bytes_bf16 / bytes_packed,
    }


def engine_rows(b=64, m=512, n=128, seed=0) -> list[dict]:
    """One comparable row per registered execution backend.

    Every backend runs the SAME ±1 matmul; rows report bit-exactness vs
    ``reference``, modeled sequential hardware steps (``Engine.steps_for``
    — the cost-model contract) and directional CPU wall time.
    """
    key = jax.random.key(seed)
    ka, kw = jax.random.split(key)
    a = jnp.sign(jax.random.normal(ka, (b, m))).astype(jnp.float32)
    w = jnp.sign(jax.random.normal(kw, (m, n))).astype(jnp.float32)
    out_ref = np.asarray(ref.xnor_matmul_ref(a, w))

    rows = []
    for name in engine_lib.list_engines():
        eng = engine_lib.get_engine(name)
        f = jax.jit(eng.binary_vmm)
        out = np.asarray(f(a, w)).astype(np.int64)
        rows.append({
            "engine": name,
            "hardware": eng.info.hardware,
            "bitexact": bool(np.array_equal(out, out_ref.astype(np.int64))),
            "steps": eng.steps_for(m, n, b),
            "cpu_t_s": _time(f, a, w),
        })
    return rows


def main(smoke: bool = False) -> int:
    # smoke: CI-sized shapes (interpret-mode Pallas on big shapes is slow)
    out = run(m=128, k=256, n=64) if smoke else run()
    m, k, n = out["shape"]
    print(f"\n== kernel bench: packed XNOR matmul ({m}x{k}x{n}) ==")
    print(f"bit-exact vs ref: {out['bitexact']}")
    print(f"CPU wall (directional): dense bf16 {out['cpu_t_dense_s']*1e3:.1f} ms, "
          f"packed jnp {out['cpu_t_packed_s']*1e3:.1f} ms")
    print(f"HBM traffic: bf16 {out['hbm_bytes_bf16']/2**20:.1f} MiB -> "
          f"packed {out['hbm_bytes_packed']/2**20:.1f} MiB "
          f"({out['traffic_reduction']:.0f}x reduction — the paper's 1-bit/cell density)")

    rows = engine_rows(b=16, m=128, n=32) if smoke else engine_rows()
    print("\n== engine sweep: registered backends, one ±1 matmul "
          f"({'16x128x32' if smoke else '64x512x128'}) ==")
    print(f"{'engine':>14s} {'bit-exact':>9s} {'hw steps':>9s} {'cpu_ms':>8s}  hardware")
    for r in rows:
        print(f"{r['engine']:>14s} {str(r['bitexact']):>9s} {r['steps']:>9d} "
              f"{r['cpu_t_s']*1e3:8.1f}  {r['hardware']}")
    ok = out["bitexact"] and all(r["bitexact"] for r in rows)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
