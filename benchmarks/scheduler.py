"""Request-scheduler offered-load sweep — throughput/TTFT/rejection vs
arrival rate × K × engine (``BENCH_scheduler.json``).

The PR-7 request path (``repro.serving.scheduler``) exists so bursty,
over-subscribed traffic keeps the slot pool saturated without breaking
the bit-exactness contract. This sweep drives it the way a load test
drives a server:

* **Measured**: requests arrive at a configured rate (requests per
  scheduling tick, fractional rates accumulate) against a
  ``max_batch``-slot pool, under two scheduler variants — the FIFO /
  whole-admission baseline and a pressured deadline / partial-admission
  config (tight KV reserve, bounded queue, mixed priorities) that
  exercises preemption, graceful rejection and budget reconciliation.
  Reports per (engine × K × rate × variant): wall-clock throughput,
  ticks-to-first-token, admission wait, rejections, expirations,
  preemptions.
* **Gates** (CI runs this in smoke mode): every FINISHED request's
  generation must be byte-identical to its solo single-slot reference,
  every EXPIRED request's partial output must be a strict prefix of it,
  and every run must drain within the tick cap — an admission deadlock
  under budget pressure fails the section.
* **Modeled**: ``costmodel.scheduled_decode_tick`` across admitted
  widths — what a partially-admitted tick costs on the placed hardware
  and how much provisioned lane capacity admission control leaves dark.

    PYTHONPATH=src python -m benchmarks.scheduler [--smoke]
"""

from __future__ import annotations

import dataclasses

from benchmarks import _timing

TICK_CAP = 2_000  # deadlock gate: no smoke run needs remotely this many


def _bench_model():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import lm as lm_lib

    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def _prompts(n, lengths=(5, 3, 4)):
    import numpy as np

    rng = np.random.default_rng(0)
    return [
        rng.integers(1, 1000, (lengths[i % len(lengths)],), dtype=np.int32)
        for i in range(n)
    ]


def _solo_refs(cm, prompts, gen, max_len):
    """Each request alone in a 1-slot pool: the byte-exactness oracle."""
    from repro.serving import Request

    refs = {}
    for i, p in enumerate(prompts):
        se = cm.serve(max_batch=1, max_len=max_len)
        st = se.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
        se.drain()
        refs[i] = tuple(st.generated)
    return refs


def _offered_load(cm, prompts, refs, *, rate, sched, max_batch, max_len, gen):
    """Drive one run: arrivals at ``rate`` requests/tick, step to drain."""
    from repro.serving import Request, RequestStatus

    se = cm.serve(max_batch=max_batch, max_len=max_len, scheduler=sched)
    states, acc, nxt, ticks = [], 0.0, 0, 0
    deadlocked = False
    with _timing.Stopwatch() as sw:
        while nxt < len(prompts) or not se.idle():
            if nxt < len(prompts):
                acc += rate
                while acc >= 1.0 and nxt < len(prompts):
                    states.append(se.submit(Request(
                        rid=nxt,
                        prompt=prompts[nxt],
                        max_new_tokens=gen,
                        priority=nxt % 2,     # mixed SLOs: odd rids outrank
                    )))
                    acc -= 1.0
                    nxt += 1
            se.step()
            ticks += 1
            if ticks > TICK_CAP:
                deadlocked = True
                break
    wall = sw.seconds

    exact = True
    for st in states:
        ref = refs[st.rid]
        if st.status is RequestStatus.FINISHED and tuple(st.generated) != ref:
            exact = False
        if (st.status is RequestStatus.EXPIRED
                and tuple(st.generated) != ref[: len(st.generated)]):
            exact = False
    s = se.stats()
    toks = sum(len(st.generated) for st in states)
    return {
        "rate": rate,
        "ticks": ticks,
        "wall_ms": wall * 1e3,
        "tok_s": toks / max(wall, 1e-9),
        "finished": s.scheduler.finished,
        "rejected": s.scheduler.rejected,
        "expired": s.scheduler.expired,
        "preempted": s.scheduler.preempted,
        "resumed": s.scheduler.resumed,
        "ttft_ticks": s.scheduler.ticks_to_first_token,
        "admission_wait_ticks": s.scheduler.admission_wait_ticks,
        "max_queue_depth": s.scheduler.max_queue_depth,
        "pad_lanes": s.pad_lanes,
        "exact": exact,
        "deadlocked": deadlocked,
    }


def measured_sweep(engines, ks, rates, *, n_requests, gen, max_batch):
    from repro import compiler as compiler_lib
    from repro.serving import SchedulerConfig

    cfg, params = _bench_model()
    prompts = _prompts(n_requests)
    max_len = max(len(p) for p in prompts) + gen + 2
    variants = {
        "fifo/whole": SchedulerConfig(),
        # pressure: EDF ordering, optimistic admission against a halved
        # budget, a bounded queue, preemption across the priority mix
        "deadline/partial": SchedulerConfig(
            policy="deadline", admission="partial",
            kv_reserve_ratio=0.5, max_waiting=max(2, n_requests // 2),
        ),
    }

    rows = []
    for engine in engines:
        for k in ks:
            cm = compiler_lib.compile(
                cfg, params,
                compiler_lib.HardwareTarget(engine=engine, group_size=k),
            )
            refs = _solo_refs(cm, prompts, gen, max_len)
            for rate in rates:
                for label, sched in variants.items():
                    row = _offered_load(
                        cm, prompts, refs, rate=rate, sched=sched,
                        max_batch=max_batch, max_len=max_len, gen=gen,
                    )
                    row.update(engine=engine, k=k, variant=label)
                    rows.append(row)
    return rows


def modeled_sweep(pool=8):
    """scheduled_decode_tick across admitted widths on the paper's plan."""
    from repro.core import costmodel as cm
    from repro.core.crossbar import OPCM_TILE
    from repro.mapping import compile_plan

    cfg, _ = _bench_model()
    plan = compile_plan(cfg, spec=OPCM_TILE, policy="tacitmap")
    return [
        cm.scheduled_decode_tick(plan, n, pool)
        for n in range(0, pool + 1, max(1, pool // 8))
    ]


def run(smoke: bool = False) -> tuple[int, dict]:
    if smoke:
        engines, ks = ("reference", "wdm"), (1, 4)
        sizes = dict(n_requests=6, gen=4, max_batch=2)
        rates = (0.5, 2.0)
    else:
        engines, ks = ("reference", "wdm", "packed", "tiled"), (1, 2, 4)
        sizes = dict(n_requests=12, gen=6, max_batch=4)
        rates = (0.25, 1.0, 4.0)

    rows = measured_sweep(engines, ks, rates, **sizes)

    print("\n== request-scheduler offered-load sweep (smoke LM, "
          f"pool={sizes['max_batch']}, {sizes['n_requests']} requests, "
          f"gen={sizes['gen']}) ==")
    print(f"{'engine':>10s} {'K':>3s} {'rate':>5s} {'variant':>17s} "
          f"{'tok/s':>8s} {'ttft':>6s} {'wait':>6s} {'fin':>4s} {'rej':>4s} "
          f"{'exp':>4s} {'pre':>4s} {'depth':>6s} {'exact':>6s}")
    for r in rows:
        print(f"{r['engine']:>10s} {r['k']:3d} {r['rate']:5.2f} "
              f"{r['variant']:>17s} {r['tok_s']:8.1f} {r['ttft_ticks']:6.2f} "
              f"{r['admission_wait_ticks']:6.2f} {r['finished']:4d} "
              f"{r['rejected']:4d} {r['expired']:4d} {r['preempted']:4d} "
              f"{r['max_queue_depth']:6d} {str(r['exact']):>6s}")

    exact = all(r["exact"] for r in rows)
    no_deadlock = not any(r["deadlocked"] for r in rows)
    pressured = [r for r in rows if r["variant"] == "deadline/partial"]
    # admission control must actually act under pressure somewhere in
    # the grid (queueing, rejection or preemption), or the sweep proves
    # nothing about the scheduler
    acted = any(
        r["preempted"] or r["rejected"] or r["max_queue_depth"] > 0
        for r in pressured
    )
    print(f"\nscheduled == solo (finished exact, expired prefix-exact): {exact}")
    print(f"all runs drained within {TICK_CAP} ticks (no admission "
          f"deadlock): {no_deadlock}")
    print(f"admission control exercised under pressure: {acted}")

    ticks = modeled_sweep()
    print("\n== modeled scheduled decode tick (tacitmap plan, "
          f"pool={ticks[-1].pool}) ==")
    print(f"{'admitted':>9s} {'groups':>7s} {'latency_ns':>11s} "
          f"{'energy_pJ':>10s} {'idle_lanes':>10s} {'tok/s':>12s}")
    for t in ticks:
        print(f"{t.n_admitted:9d} {t.groups:7d} {t.latency_ns:11.0f} "
              f"{t.energy_pj:10.1f} {t.idle_lane_fraction:9.0%} "
              f"{t.tokens_per_s:12.2e}")
    print("(a partially-admitted tick only pays for the K-groups it "
          "issues; the idle column is the provisioned capacity admission "
          "control leaves dark)")

    rc = 0 if (exact and no_deadlock and acted) else 1
    payload = {
        "measured": rows,
        "modeled": [dataclasses.asdict(t) for t in ticks],
        "bit_exact_vs_solo": exact,
        "no_deadlock": no_deadlock,
        "admission_exercised": acted,
    }
    return rc, payload


def main(smoke: bool = False) -> int:
    return run(smoke=smoke)[0]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweep")
    raise SystemExit(main(smoke=ap.parse_args().smoke))
