"""Serving decode-tick latency — prepared vs unprepared weights, per
engine × K. The first *measured serving latency* point in the perf
trajectory (``BENCH_serving.json``): the PR ≤ 3 artifacts recorded only
mapping sweeps.

Two views of the PR-4 prepared-weights contract:

* **Measured**: one :class:`repro.compiler.HardwareTarget` per
  (engine, K), served twice through ``compile(...).serve(...)`` — once
  with the crossbar-programming phase (default: weights are compiled
  into the backend's resident form once, decode streams only
  activations) and once with the same target's
  ``prepare_weights=False`` (the PR-3 behaviour: every tick re-runs
  ``map_weights`` / bit-packing / block gathers per projection inside
  the decode graph). Reports the median
  decode-tick wall time over a full, steady slot pool plus the one-time
  programming wall time. The gate asserts prepared ticks are strictly
  faster for ``packed``/``wdm``/``tiled`` and that both paths decode
  bit-identical tokens.
* **Modeled**: the cost model's one-time programming-energy term (PCM
  write, ``costmodel.layer_programming_cost``) against the per-tick
  readout energy — the break-even tick count after which the
  stationary-weight premise has paid for its write.
"""

from __future__ import annotations

import dataclasses
import statistics

from benchmarks import _timing

GATE_ENGINES = ("packed", "wdm", "tiled")


def _bench_model(max_batch: int, prompt_len: int):
    """The shared smoke LM + prompt set every paired sweep serves."""
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import lm as lm_lib

    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(1, cfg.vocab_size, (prompt_len,), dtype=np.int32)
        for _ in range(max_batch)
    ]
    return cfg, params, prompts


def _paired_servers(cfg, params, prompts, variants, *, max_batch, prompt_len,
                    warmup, ticks, budget):
    """Serve one engine per target variant and time their decode ticks
    interleaved (the shared :mod:`benchmarks._timing` methodology —
    per-pair deltas cancel machine drift).

    ``variants`` is an ordered {label: HardwareTarget}; returns
    ({label: server}, {label: [tick seconds]}).
    """
    from repro import compiler as compiler_lib
    from repro.serving import Request

    pair = {}
    for label, tgt in variants.items():
        se = compiler_lib.compile(cfg, params, tgt).serve(
            max_batch=max_batch, max_len=prompt_len + budget + 2
        )
        for i, p in enumerate(prompts):
            se.submit(Request(rid=i, prompt=p, max_new_tokens=budget))
        # first steps admit+prefill+compile; excluded from timing
        for _ in range(warmup):
            se.step()
        pair[label] = se
    return pair, _timing.interleaved_ticks(pair, ticks=ticks)


def _slot_gens(se):
    """Per-slot generated-token streams (same admission order across a
    pair, so equal dicts == bit-identical decode)."""
    return {
        slot: tuple(st.generated)
        for slot, st in sorted(se.scheduler.running.items())
    }


def measured_sweep(targets, *, max_batch, prompt_len, warmup, ticks):
    cfg, params, prompts = _bench_model(max_batch, prompt_len)
    budget = warmup + ticks + 2  # slots stay active through the window

    rows = []
    for target in targets:
        row = {"engine": target.engine, "k": target.group_size}
        # The prepared/raw pair is the SAME target with prepare_weights
        # flipped — the one-knob ablation the HardwareTarget makes
        # explicit (raw re-runs map_weights/bit-packing per tick).
        pair, times = _paired_servers(
            cfg, params, prompts,
            {
                "prepared": target,
                "raw": dataclasses.replace(target, prepare_weights=False),
            },
            max_batch=max_batch, prompt_len=prompt_len,
            warmup=warmup, ticks=ticks, budget=budget,
        )
        for label in pair:
            row[f"tick_ms_{label}"] = statistics.median(times[label]) * 1e3
        row["paired_deltas_ms"] = _timing.paired_deltas(
            times["prepared"], times["raw"], scale=1e3
        )
        row["paired_delta_ms"] = _timing.pooled_median(row["paired_deltas_ms"])
        prepared_stats = pair["prepared"].stats()
        row["programmed"] = prepared_stats.programmed
        row["program_ms"] = prepared_stats.program_s * 1e3
        gens = {label: _slot_gens(se) for label, se in pair.items()}
        row["speedup"] = row["tick_ms_raw"] / max(row["tick_ms_prepared"], 1e-9)
        row["exact"] = gens["prepared"] == gens["raw"] and bool(gens["prepared"])
        rows.append(row)
    return rows


def fused_sweep(ks, *, max_batch, prompt_len, warmup, ticks,
                d_model=512, d_ff=1024):
    """Fused vs unfused packed decode ticks, per K.

    Same target with ``fused`` flipped: the fused path runs each
    prepared projection as ONE ``kernels/fused_decode.py`` launch (with
    q/k/v sharing a single launch over the concatenated artifact); the
    unfused baseline keeps the PR-4 chain — binarize, ``pack_bits``,
    Hamming kernel, affine correction and rescale as separate ops, three
    of everything for q/k/v. Decode streams must stay bit-identical.

    The sweep widens the smoke LM to ``d_model``/``d_ff`` (default
    512/1024): at the smoke width (d=64 -> 2 packed words per row) every
    launch is pinned to the interpreter's fixed per-call floor and the
    pooled delta is sign-flipping noise, while at 512/1024 the
    structural difference — one launch vs binarize/pack/Hamming/rescale
    chains, three of them for q/k/v — dominates that floor and the gate
    measures the kernel rather than the harness.
    """
    import jax

    from repro.compiler import HardwareTarget
    from repro.models import lm as lm_lib

    cfg, params, prompts = _bench_model(max_batch, prompt_len)
    cfg = dataclasses.replace(cfg, d_model=d_model, d_ff=d_ff)
    params = lm_lib.init_params(jax.random.key(0), cfg)
    budget = warmup + ticks + 2

    rows = []
    for k in ks:
        target = HardwareTarget(engine="packed", group_size=k)
        pair, times = _paired_servers(
            cfg, params, prompts,
            {
                "fused": target,
                "unfused": dataclasses.replace(target, fused=False),
            },
            max_batch=max_batch, prompt_len=prompt_len,
            warmup=warmup, ticks=ticks, budget=budget,
        )
        row = {"engine": "packed", "k": k}
        for label in pair:
            row[f"tick_ms_{label}"] = statistics.median(times[label]) * 1e3
        row["paired_deltas_ms"] = _timing.paired_deltas(
            times["fused"], times["unfused"], scale=1e3
        )
        row["paired_delta_ms"] = _timing.pooled_median(row["paired_deltas_ms"])
        gens = {label: _slot_gens(se) for label, se in pair.items()}
        row["speedup"] = row["tick_ms_unfused"] / max(row["tick_ms_fused"], 1e-9)
        row["exact"] = gens["fused"] == gens["unfused"] and bool(gens["fused"])
        rows.append(row)
    return rows


def modeled_programming():
    from repro.core import costmodel as cm
    from repro.core.networks import LayerDesc

    layer = LayerDesc(name="fc", m=512, n=512, positions=1, binary=True)
    out = []
    for p in (cm.EINSTEINBARRIER, cm.TACITMAP_EPCM):
        prog = cm.layer_programming_cost(p, layer)
        tick = cm.grouped_decode_tick(p, layer, n_active=16)
        out.append({
            "design": p.name,
            "cells": prog.cells,
            "program_uJ": prog.energy_pj * 1e-6,
            "program_us": prog.time_ns * 1e-3,
            "tick_energy_pJ": tick.energy_pj,
            "break_even_ticks": cm.programming_break_even_ticks(p, layer, 16),
        })
    return layer, out


def run(smoke: bool = False, engines=None, ks=None) -> tuple[int, dict]:
    from repro.compiler import HardwareTarget

    if smoke:
        engines = engines or GATE_ENGINES
        ks = ks or (1, 4)
        sizes = dict(max_batch=4, prompt_len=5, warmup=3, ticks=20)
    else:
        engines = engines or GATE_ENGINES + ("tacitmap",)
        ks = ks or (1, 2, 4)
        sizes = dict(max_batch=4, prompt_len=6, warmup=3, ticks=32)

    # one HardwareTarget per (engine, K); measured_sweep flips each
    # target's prepare_weights for the prepared-vs-raw pair
    targets = [
        HardwareTarget(engine=name, group_size=k)
        for name in engines for k in ks
    ]
    rows = measured_sweep(targets, **sizes)

    print("\n== serving decode-tick latency: prepared vs raw weights "
          f"(smoke LM, batch={sizes['max_batch']}, median of {sizes['ticks']} "
          "interleaved tick pairs) ==")
    print(f"{'engine':>10s} {'K':>3s} {'prepared_ms':>12s} {'raw_ms':>9s} "
          f"{'speedup':>8s} {'pair_d_ms':>10s} {'exact':>6s} {'program_ms':>11s}")
    for r in rows:
        print(f"{r['engine']:>10s} {r['k']:3d} {r['tick_ms_prepared']:12.2f} "
              f"{r['tick_ms_raw']:9.2f} {r['speedup']:7.2f}x "
              f"{r['paired_delta_ms']:10.3f} {str(r['exact']):>6s} "
              f"{r['program_ms']:11.1f}")

    exact = all(r["exact"] for r in rows)
    # acceptance gate, per ENGINE: pool the interleaved per-tick deltas
    # across that engine's K rows — prepared must be strictly faster
    deltas = {}
    for r in rows:
        if r["engine"] in GATE_ENGINES:
            deltas.setdefault(r["engine"], []).extend(r["paired_deltas_ms"])
    per_engine = {e: _timing.pooled_median(d) for e, d in deltas.items()}
    # the gate must not pass vacuously: an --engine restriction that
    # sweeps no gate engine SKIPS the gate (None, reported as such)
    # rather than claiming packed/wdm/tiled were measured faster
    faster = all(d > 0 for d in per_engine.values()) if per_engine else None
    print("per-engine pooled median tick delta (raw - prepared, ms): "
          + "  ".join(f"{e}={d:+.3f}" for e, d in per_engine.items()))
    if per_engine:
        print(f"prepared strictly faster on {'/'.join(sorted(per_engine))}: "
              f"{faster}; bit-exact prepared vs raw: {exact}")
    else:
        print("prepared-faster gate SKIPPED (no gate engine swept); "
              f"bit-exact prepared vs raw: {exact}")
    print("(raw re-runs the weight-side transforms inside every decode tick; "
          "prepared programs them once at engine bind — the CIM premise)")

    # fused-vs-unfused packed decode tick: the PR-6 fused decode-tick
    # kernel against the multi-op baseline, same pooled-median gate
    fused_rows = fused_sweep(ks, **sizes) if "packed" in engines else []
    fused_deltas = [d for r in fused_rows for d in r["paired_deltas_ms"]]
    fused_exact = all(r["exact"] for r in fused_rows) if fused_rows else True
    fused_faster = (
        _timing.pooled_median(fused_deltas) > 0 if fused_deltas else None
    )
    if fused_rows:
        print("\n== packed decode tick: fused kernel vs unfused baseline ==")
        print(f"{'K':>3s} {'fused_ms':>9s} {'unfused_ms':>11s} {'speedup':>8s} "
              f"{'pair_d_ms':>10s} {'exact':>6s}")
        for r in fused_rows:
            print(f"{r['k']:3d} {r['tick_ms_fused']:9.2f} "
                  f"{r['tick_ms_unfused']:11.2f} {r['speedup']:7.2f}x "
                  f"{r['paired_delta_ms']:10.3f} {str(r['exact']):>6s}")
        print(f"fused strictly faster (pooled median across K): {fused_faster}; "
              f"bit-exact fused vs unfused: {fused_exact}")
        print("(fused: binarize+pack+XNOR+popcount+affine+rescale in one "
              "kernel launch, q/k/v sharing one pass; unfused: the same "
              "steps as separate per-projection XLA ops)")
    else:
        print("\nfused-vs-unfused gate SKIPPED ('packed' not swept)")

    layer, modeled = modeled_programming()
    print(f"\n== modeled one-time programming vs per-tick readout "
          f"({layer.m}x{layer.n} FC, 16 active slots) ==")
    print(f"{'design':>16s} {'cells':>8s} {'write_uJ':>9s} {'write_us':>9s} "
          f"{'tick_pJ':>9s} {'break-even':>11s}")
    for m in modeled:
        print(f"{m['design']:>16s} {m['cells']:8d} {m['program_uJ']:9.2f} "
              f"{m['program_us']:9.1f} {m['tick_energy_pJ']:9.1f} "
              f"{m['break_even_ticks']:9.0f}t")
    print("(PCM writes cost ~10^4 reads; the write amortizes over the decode "
          "stream — the prepared-weights contract is that amortization in software)")

    rc = 0 if (
        exact and faster is not False
        and fused_exact and fused_faster is not False
    ) else 1
    payload = {
        "measured": rows,
        "modeled": {"layer": {"m": layer.m, "n": layer.n}, "designs": modeled},
        "prepared_strictly_faster": faster,
        "bit_exact": exact,
        "fused": fused_rows,
        "fused_strictly_faster": fused_faster,
        "fused_bit_exact": fused_exact,
    }
    return rc, payload


def main(smoke: bool = False, engines=None, ks=None) -> int:
    return run(smoke=smoke, engines=engines, ks=ks)[0]


if __name__ == "__main__":
    import argparse

    from repro.compiler import add_target_args, target_from_args

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweep")
    # shared target flags; --engine/--group-size restrict the sweep axes
    add_target_args(ap, default_engine=None)
    args = ap.parse_args()
    try:
        tgt = target_from_args(args)
    except Exception as e:
        ap.error(str(e))
    # no silent knob drops: the flags this sweep does not consume are
    # rejected, not accepted-and-ignored
    if tgt.wants_plan or not tgt.prepare_weights:
        ap.error("--mapping-policy/--tile-budget/--raw-weights do not apply: "
                 "this sweep grids engine x K and flips prepare_weights "
                 "itself (the prepared-vs-raw pair)")
    raise SystemExit(main(
        smoke=args.smoke,
        engines=(tgt.engine,) if args.engine else None,
        ks=(tgt.group_size,) if tgt.group_size else None,
    ))
