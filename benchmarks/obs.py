"""Telemetry gate — traced serving, measured-vs-modeled pricing, and
the disabled-path overhead bound (``BENCH_obs.json``).

The PR-8 observability subsystem (:mod:`repro.obs`) makes three
promises this section holds it to, per (engine x K) on the smoke LM:

* **Crosscheck sanity**: a traced serve must yield a
  measured-vs-modeled decode-tick ratio that is finite and strictly
  positive for every (engine, K) swept — and the expected spans
  (compile stages, prefill, decode ticks) must actually be present in
  the trace. The ratio's *level* is not gated (the host emulates
  nanosecond photonics, so >>1 is expected); the artifact records it as
  a fidelity trajectory across PRs.
* **Bit-exactness**: generation with tracing on must be byte-identical
  to the same serve with telemetry off — instrumentation must never
  change tokens.
* **Near-zero when off**: with no active session, ``obs.span()`` is one
  ``None`` check returning a shared no-op; the microbench bounds its
  per-call cost (generous CI bound — the gate catches accidental
  allocation/clock/sync on the disabled path, not nanosecond drift).

Also writes a sample Chrome trace (``trace.json``) so CI uploads a
loadable artifact next to the JSON.

    PYTHONPATH=src python -m benchmarks.obs [--smoke]
"""

from __future__ import annotations

import dataclasses
import statistics
import time

# disabled-path bound: median ns per obs.span() call with telemetry off.
# The real cost is ~100ns (one None check + returning a singleton); 20us
# catches a reintroduced allocation/clock/host-sync without flaking CI.
DISABLED_NS_BOUND = 20_000


def _bench_model():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import lm as lm_lib

    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def _prompts(n, max_len=5):
    import numpy as np

    rng = np.random.default_rng(0)
    return [
        rng.integers(1, 1000, (3 + i % max_len,), dtype=np.int32)
        for i in range(n)
    ]


def _serve(cm, prompts, *, gen, max_batch, max_len):
    """One drained serve; returns ({rid: tokens}, ServingEngine)."""
    from repro.serving import Request

    se = cm.serve(max_batch=max_batch, max_len=max_len)
    states = [
        se.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
        for i, p in enumerate(prompts)
    ]
    se.drain()
    return {st.rid: tuple(st.generated) for st in states}, se


def traced_rows(engines, ks, *, n_requests, gen, max_batch):
    """Per (engine, K): serve traced AND untraced, gate bit-exactness,
    and cross-check every traced tick against the cost model."""
    from repro import compiler as compiler_lib
    from repro import obs

    cfg, params = _bench_model()
    prompts = _prompts(n_requests)
    max_len = max(len(p) for p in prompts) + gen + 2

    rows = []
    sample_tracer = None
    for engine in engines:
        for k in ks:
            target = compiler_lib.HardwareTarget(engine=engine, group_size=k)
            # telemetry OFF: the reference generation
            cm = compiler_lib.compile(cfg, params, target)
            plain, _ = _serve(
                cm, prompts, gen=gen, max_batch=max_batch, max_len=max_len
            )
            # telemetry ON: same target, full session (compile included,
            # so the pipeline-stage spans land in the sample trace)
            with obs.session() as tel:
                cm = compiler_lib.compile(cfg, params, target)
                traced, se = _serve(
                    cm, prompts, gen=gen, max_batch=max_batch, max_len=max_len
                )
                checks = obs.crosscheck_serving(se, tracer=tel.tracer)
            sample_tracer = tel.tracer

            spans_present = all(
                tel.tracer.spans(name)
                for name in ("compile", "prefill", "decode_tick")
            )
            for c in checks:
                rows.append({
                    "engine": engine,
                    "k": c.k,
                    "ticks": c.ticks,
                    "n_active_mean": c.n_active_mean,
                    "measured_us": c.measured_ns * 1e-3,
                    "modeled_ns": c.modeled_ns,
                    "ratio": c.ratio,
                    "ratio_finite": c.finite,
                    "spans_present": spans_present,
                    "bit_exact": traced == plain and bool(plain),
                })
    return rows, sample_tracer


def disabled_overhead(reps: int) -> dict:
    """Median ns of the no-op telemetry path (no active session)."""
    from repro import obs

    assert not obs.enabled(), "disabled-path bench needs telemetry off"

    def once(n):
        t0 = time.perf_counter_ns()
        for _ in range(n):
            with obs.span("tick", track="serve", engine="none", k=1):
                pass
        return (time.perf_counter_ns() - t0) / n

    once(reps)  # warm the helper path
    per_call = [once(reps) for _ in range(7)]
    return {
        "span_ns_per_call": statistics.median(per_call),
        "bound_ns": DISABLED_NS_BOUND,
        "within_bound": statistics.median(per_call) < DISABLED_NS_BOUND,
    }


def run(smoke: bool = False, trace_out: str | None = "trace.json"):
    if smoke:
        engines, ks = ("wdm", "tiled"), (1, 4)
        sizes = dict(n_requests=4, gen=4, max_batch=2)
        reps = 2_000
    else:
        engines, ks = ("reference", "wdm", "packed", "tiled"), (1, 2, 4)
        sizes = dict(n_requests=6, gen=6, max_batch=4)
        reps = 20_000

    rows, sample_tracer = traced_rows(engines, ks, **sizes)

    print("\n== telemetry gate: traced serving, measured-vs-modeled "
          f"pricing (smoke LM, pool={sizes['max_batch']}) ==")
    print(f"{'engine':>10s} {'K':>3s} {'ticks':>6s} {'measured_us':>12s} "
          f"{'modeled_ns':>11s} {'ratio':>10s} {'finite':>7s} {'spans':>6s} "
          f"{'exact':>6s}")
    for r in rows:
        print(f"{r['engine']:>10s} {r['k']:3d} {r['ticks']:6d} "
              f"{r['measured_us']:12.1f} {r['modeled_ns']:11.1f} "
              f"{r['ratio']:10.1f} {str(r['ratio_finite']):>7s} "
              f"{str(r['spans_present']):>6s} {str(r['bit_exact']):>6s}")

    finite = all(r["ratio_finite"] for r in rows)
    spans = all(r["spans_present"] for r in rows)
    exact = all(r["bit_exact"] for r in rows)
    print(f"every measured/modeled ratio finite and > 0: {finite}")
    print(f"compile/prefill/decode_tick spans present in every trace: {spans}")
    print(f"tracing on vs off bit-identical generations: {exact}")

    off = disabled_overhead(reps)
    print(f"\ndisabled-path span overhead: {off['span_ns_per_call']:.0f} ns/call "
          f"(bound {off['bound_ns']} ns) -> within bound: {off['within_bound']}")
    print("(off-by-default contract: one None check, a shared no-op span, "
          "no clock reads and no host synchronization)")

    if trace_out and sample_tracer is not None:
        sample_tracer.export_chrome(trace_out)
        print(f"[obs] wrote sample Chrome trace -> {trace_out}")

    rc = 0 if (finite and spans and exact and off["within_bound"]) else 1
    payload = {
        "crosscheck": rows,
        "disabled_overhead": off,
        "ratios_finite": finite,
        "spans_present": spans,
        "bit_exact": exact,
    }
    return rc, payload


def main(smoke: bool = False) -> int:
    return run(smoke=smoke)[0]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweep")
    raise SystemExit(main(smoke=ap.parse_args().smoke))
