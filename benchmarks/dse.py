"""Design-space exploration of oPCM VCores (paper §VI-C future work).

The paper evaluates ONE fixed configuration (256x256 tiles, K=16,
fixed laser) citing limited component specs. The cost model makes the
sweep cheap: crossbar geometry x WDM capacity x laser power, reporting
per-image latency, energy, and the transmitter/TIA overhead share —
the pareto the paper asks for.

    PYTHONPATH=src python -m benchmarks.dse
"""

from __future__ import annotations

import dataclasses

from repro.core import costmodel as cm
from repro.core.networks import NETWORKS


def explore(net_name: str = "CNN-M"):
    net = NETWORKS[net_name]
    rows = []
    for size in (128, 256, 512):
        for k in (4, 8, 16, 32):
            for laser in (100.0, 200.0, 400.0):
                tile = dataclasses.replace(
                    cm.EINSTEINBARRIER.tile, rows=size, cols=size, wdm_k=k
                )
                p = dataclasses.replace(cm.EINSTEINBARRIER, tile=tile, p_laser_mw=laser)
                lat = cm.network_latency_s(p, net)
                en = cm.network_energy_j(p, net)
                tx_mw = cm.transmitter_power_mw(p)
                rows.append({
                    "size": size, "k": k, "laser_mw": laser,
                    "latency_us": lat * 1e6, "energy_uj": en * 1e6,
                    "tx_power_w": tx_mw / 1e3,
                })
    return rows


def pareto(rows):
    """3-objective front: latency, energy, AND transmitter wall power —
    Eq. 3 grows ~K*M, so 'fastest' configs carry real power budgets."""
    keys = ("latency_us", "energy_uj", "tx_power_w")

    def dominates(o, r):
        return all(o[k] <= r[k] for k in keys) and any(o[k] < r[k] for k in keys)

    out = [r for r in rows if not any(dominates(o, r) for o in rows)]
    return sorted(out, key=lambda r: r["latency_us"])


def main() -> int:
    rows = explore()
    front = pareto(rows)
    print("\n== oPCM VCore design-space exploration (CNN-M) ==")
    print(f"{len(rows)} design points; pareto front (latency vs energy):")
    print(f"{'tile':>6s} {'K':>4s} {'laser':>7s} {'lat_us':>8s} {'E_uJ':>8s} {'tx_W':>6s}")
    for r in front:
        print(f"{r['size']:4d}^2 {r['k']:4d} {r['laser_mw']:5.0f}mW "
              f"{r['latency_us']:8.3f} {r['energy_uj']:8.3f} {r['tx_power_w']:6.1f}")
    # structural sanity: bigger K never hurts latency; bigger tiles
    # amortize edge layers but raise transmitter power (Eq. 3 ~ K*M)
    base = [r for r in rows if r["size"] == 256 and r["laser_mw"] == 200.0]
    lat_by_k = {r["k"]: r["latency_us"] for r in base}
    ok = lat_by_k[32] <= lat_by_k[16] <= lat_by_k[8] <= lat_by_k[4]
    print(f"  [{'PASS' if ok else 'FAIL'}] latency monotone non-increasing in K (fixed tile)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
