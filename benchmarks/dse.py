"""Design-space exploration — a hardware-target grid priced through
``CompiledModel.price()`` (the ROADMAP's "Mapping DSE" open item,
paper §VI-C future work).

The paper evaluates ONE fixed configuration (256x256 tiles, K=16, one
mapping); the compiler API makes the sweep one loop: every grid point
is a :class:`repro.compiler.HardwareTarget` — allocator policy x
physical tile budget x WDM capacity K on oPCM tiles — compiled
*price-only* (no params) against the LM serving target and priced in
one report (plan schedule + one-time programming + per-tick readout).
The output is the latency-vs-area pareto (area = provisioned tiles:
a tile budget below the block count forces co-residency, and the
plan's ``steps_per_vector`` serialization surfaces directly in
latency), written as ``BENCH_dse.json`` by ``benchmarks/run.py --out``
— the third perf-trajectory artifact in CI.

    PYTHONPATH=src python -m benchmarks.dse [--smoke] [--mapping-policy P]
"""

from __future__ import annotations

import dataclasses

ARCH = "qwen1.5-0.5b"


def target_grid(smoke: bool, policies=None, budgets=None):
    """The swept HardwareTargets: policy x tile budget x WDM K."""
    from repro.core.crossbar import OPCM_TILE
    from repro.compiler import HardwareTarget
    from repro.configs import get_config
    from repro.mapping import POLICIES, required_tiles

    cfg = get_config(ARCH)
    need = required_tiles(cfg, OPCM_TILE)
    policies = tuple(policies or POLICIES)
    ks = (4, 16) if smoke else (4, 8, 16, 32)
    if budgets is None:
        budgets = (None, 64) if smoke else (None, max(1, need // 2), 64)
    targets = []
    for policy in policies:
        for budget in budgets:
            for k in ks:
                spec = dataclasses.replace(OPCM_TILE, wdm_k=k)
                targets.append(HardwareTarget(
                    engine="tiled", spec=spec, mapping_policy=policy,
                    tile_budget=budget,
                ))
    return cfg, targets


def explore(smoke: bool, policies=None, budgets=None) -> list[dict]:
    """Compile + price every target in the grid (params-free)."""
    from repro import compiler as compiler_lib

    cfg, targets = target_grid(smoke, policies, budgets)
    rows = []
    for target in targets:
        price = compiler_lib.compile(cfg, None, target).price()
        rows.append({
            "policy": target.mapping_policy,
            "tile_budget": target.tile_budget,
            "k": target.spec.wdm_k,
            "n_tiles": price.n_tiles,            # the area axis
            "utilization": round(price.utilization, 4),
            "binary_steps": price.binary_steps,
            "latency_us": price.latency_s * 1e6,
            "energy_uj": price.energy_j * 1e6,
            "program_uj": price.programming_uj,
            "program_us": price.programming_us,
            "tick_us": price.tick_latency_ns * 1e-3,
            "break_even_ticks": price.break_even_ticks,
            "design": price.design,
        })
    return rows


def fleet_axis(smoke: bool, best: dict) -> list[dict]:
    """The replica-count axis (PR 10): re-price the pareto front's best
    target across fleet sizes through ``costmodel.fleet_price`` — the
    throughput-vs-area trade replication buys on program-once CIM."""
    from repro import compiler as compiler_lib
    from repro.configs import get_config
    from repro.core import costmodel
    from repro.core.crossbar import OPCM_TILE
    from repro.compiler import HardwareTarget

    cfg = get_config(ARCH)
    spec = dataclasses.replace(OPCM_TILE, wdm_k=best["k"])
    target = HardwareTarget(
        engine="tiled", spec=spec, mapping_policy=best["policy"],
        tile_budget=best["tile_budget"],
    )
    base = compiler_lib.compile(cfg, None, target).price()
    counts = (1, 2) if smoke else (1, 2, 4, 8)
    rows = []
    for n in counts:
        fp = costmodel.fleet_price(base, n)
        rows.append({
            "replicas": n,
            "tiles_total": fp.tiles_total,
            "program_uj": fp.programming_uj,
            "program_us": fp.programming_us,
            "tick_pj": fp.tick_energy_pj,
            "fleet_tok_s": fp.fleet_tokens_per_s,
            "break_even_ticks": fp.break_even_ticks,
        })
    return rows


def pareto(rows, keys=("latency_us", "n_tiles")):
    """Non-dominated front — by default latency vs area (tiles)."""

    def dominates(o, r):
        return all(o[k] <= r[k] for k in keys) and any(o[k] < r[k] for k in keys)

    out = [r for r in rows if not any(dominates(o, r) for o in rows)]
    return sorted(out, key=lambda r: r[keys[0]])


def run(smoke: bool = False, policies=None, budgets=None) -> tuple[int, dict]:
    rows = explore(smoke, policies, budgets)
    front = pareto(rows)

    print(f"\n== target-grid DSE ({ARCH} on oPCM tiles, "
          f"policy x tile budget x K, {len(rows)} priced targets) ==")
    print(f"{'policy':>13s} {'budget':>7s} {'K':>3s} {'tiles':>7s} {'util':>6s} "
          f"{'lat_us':>9s} {'E_uJ':>8s} {'tick_us':>8s} {'brk_evn':>8s}")
    for r in rows:
        budget = "-" if r["tile_budget"] is None else str(r["tile_budget"])
        print(f"{r['policy']:>13s} {budget:>7s} {r['k']:3d} {r['n_tiles']:7d} "
              f"{r['utilization']:6.2f} {r['latency_us']:9.2f} "
              f"{r['energy_uj']:8.3f} {r['tick_us']:8.2f} "
              f"{r['break_even_ticks']:8.0f}")

    print("\nlatency-vs-area pareto front (area = provisioned tiles):")
    for r in front:
        budget = "-" if r["tile_budget"] is None else str(r["tile_budget"])
        print(f"  {r['policy']:>13s} budget={budget:>5s} K={r['k']:2d}: "
              f"{r['latency_us']:.2f} us @ {r['n_tiles']} tiles")

    # structural gates: the sweep must be a real design space —
    # (a) enough priced points for a trajectory (the unrestricted grid
    # CI records needs >= 12; a --mapping-policy/--tile-budget-
    # restricted sweep just needs every requested target priced),
    # (b) WDM K divides the stream (latency monotone non-increasing in
    # K at fixed policy/budget), (c) shrinking the tile pool never
    # speeds a fixed policy up (co-residency only serializes)
    min_points = 12 if (policies is None and budgets is None) else 1
    enough = len(rows) >= min_points
    by_axis: dict[tuple, dict[int, float]] = {}
    for r in rows:
        by_axis.setdefault((r["policy"], r["tile_budget"]), {})[r["k"]] = r["latency_us"]
    k_monotone = all(
        all(lat[a] >= lat[b] - 1e-9 for a, b in zip(sorted(lat), sorted(lat)[1:]))
        for lat in by_axis.values()
    )
    by_k: dict[tuple, dict] = {}
    for r in rows:
        by_k.setdefault((r["policy"], r["k"]), {})[r["tile_budget"]] = r["latency_us"]
    budget_costs = all(
        all(lat[b] >= lat[None] - 1e-9 for b in lat if b is not None)
        for lat in by_k.values() if None in lat
    )
    # the replica-count axis: the front's fastest target re-priced
    # across fleet sizes (PR 10 fleet serving)
    fleet = fleet_axis(smoke, front[0]) if front else []
    if fleet:
        print(f"\nfleet replica axis (best front target: "
              f"{front[0]['policy']}, K={front[0]['k']}):")
        print(f"{'N':>3s} {'tiles':>7s} {'prog_uJ':>8s} {'prog_us':>8s} "
              f"{'fleet tok/s':>12s}")
        for r in fleet:
            print(f"{r['replicas']:3d} {r['tiles_total']:7d} "
                  f"{r['program_uj']:8.2f} {r['program_us']:8.1f} "
                  f"{r['fleet_tok_s']:12.2e}")
    base_f = fleet[0] if fleet else None
    fleet_linear = all(
        r["tiles_total"] == r["replicas"] * base_f["tiles_total"]
        and abs(r["fleet_tok_s"] - r["replicas"] * base_f["fleet_tok_s"]) < 1e-3
        and r["program_us"] == base_f["program_us"]
        for r in fleet
    ) if fleet else False

    ok = enough and k_monotone and budget_costs and bool(front) and fleet_linear
    print(f"\n[{'PASS' if enough else 'FAIL'}] >= {min_points} priced target "
          f"points ({len(rows)})")
    print(f"[{'PASS' if k_monotone else 'FAIL'}] latency monotone non-increasing in K")
    print(f"[{'PASS' if budget_costs else 'FAIL'}] tile budgets never beat dedicated tiles")
    print(f"[{'PASS' if fleet_linear else 'FAIL'}] fleet pricing linear in "
          f"replicas (tiles, throughput) with flat programming wall-clock")
    payload = {"arch": ARCH, "targets": rows, "pareto": front,
               "fleet": fleet, "ok": ok}
    return (0 if ok else 1), payload


def main(smoke: bool = False, policies=None, budgets=None) -> int:
    return run(smoke=smoke, policies=policies, budgets=budgets)[0]


if __name__ == "__main__":
    import argparse

    from repro.compiler import add_target_args, target_from_args

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="12-point CI grid")
    # shared target surface; --mapping-policy/--tile-budget restrict the
    # swept axes
    add_target_args(ap, default_engine="tiled")
    args = ap.parse_args()
    try:
        tgt = target_from_args(args)
    except Exception as e:
        ap.error(str(e))
    # no silent knob drops: flags the grid does not consume are rejected
    if tgt.engine != "tiled":
        ap.error("the DSE grid prices layer->tile plans; only the "
                 "plan-driven 'tiled' engine applies")
    if tgt.group_size or not tgt.prepare_weights:
        ap.error("--group-size/--raw-weights do not apply: the grid "
                 "sweeps WDM K per target spec and prices without "
                 "executing")
    raise SystemExit(main(
        smoke=args.smoke,
        policies=(tgt.mapping_policy,) if tgt.mapping_policy else None,
        budgets=(tgt.tile_budget,) if tgt.tile_budget is not None else None,
    ))
