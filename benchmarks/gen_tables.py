"""Inject the generated §Roofline/§Dry-run tables into EXPERIMENTS.md
(replaces everything after the ROOFLINE_TABLE marker line).

    PYTHONPATH=src python -m benchmarks.gen_tables
"""

from __future__ import annotations

import json

from benchmarks.roofline import HEADER, fmt_row, load

MARKER = "<!-- ROOFLINE_TABLE -->"


def table_md(recs: list[dict]) -> str:
    lines = [HEADER]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["multi_pod"])):
        lines.append(fmt_row(r))
    ok = [r for r in recs if r["status"] == "ok"]
    doms: dict[str, int] = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    skips = len([r for r in recs if r["status"] == "skipped"])
    errs = len([r for r in recs if r["status"] == "error"])
    lines.append("")
    lines.append(
        f"**{len(ok)} cells compile+analyze, {errs} errors, {skips} documented "
        f"skips. Dominant-term histogram: {doms}.**"
    )
    return "\n".join(lines)


def main() -> int:
    recs = load("runs/dryrun")
    if not recs:
        print("no records; run repro.launch.dryrun first")
        return 1
    with open("EXPERIMENTS.md") as f:
        doc = f.read()
    head, tail = doc.split(MARKER, 1)
    # preserve everything from the first section break after the marker
    cut = tail.find("\n---")
    rest = tail[cut:] if cut != -1 else ""
    doc = head + MARKER + "\n\n" + table_md(recs) + rest
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print(f"injected {len(recs)} records into EXPERIMENTS.md")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
