"""Fig. 7 reproduction: normalized latency improvement over Baseline-ePCM
for the 6 BNN workloads, all four designs.

Paper claims checked (tolerance bands — device constants are calibrated,
see DESIGN.md §3):
  * TacitMap-ePCM:    up to ~154x, average ~78x
  * EinsteinBarrier:  ~22x … ~3113x, average ~1205x
  * EinsteinBarrier ~15x over TacitMap-ePCM
  * Baseline-ePCM vs GPU is mixed: faster on small CNNs, ~27x slower on MLP-L
"""

from __future__ import annotations

import statistics

from repro.core import costmodel as cm
from repro.core.networks import NETWORKS


def run() -> dict:
    rows = []
    for name, net in NETWORKS.items():
        r = cm.evaluate_all(net)
        base = r["Baseline-ePCM"]["latency_s"]
        rows.append({
            "network": name,
            "baseline_s": base,
            "tm_speedup": base / r["TacitMap-ePCM"]["latency_s"],
            "eb_speedup": base / r["EinsteinBarrier"]["latency_s"],
            "gpu_speedup": base / r["Baseline-GPU"]["latency_s"],
        })
    tm = [r["tm_speedup"] for r in rows]
    eb = [r["eb_speedup"] for r in rows]
    summary = {
        "tm_avg": statistics.mean(tm),
        "tm_max": max(tm),
        "eb_avg": statistics.mean(eb),
        "eb_max": max(eb),
        "eb_min": min(eb),
        "eb_over_tm_avg": statistics.mean(e / t for e, t in zip(eb, tm)),
    }
    checks = {
        "tm_max ~154x (band 100-200)": 100 <= summary["tm_max"] <= 200,
        "tm_avg ~78x (band 50-110)": 50 <= summary["tm_avg"] <= 110,
        "eb_max ~3113x (band 2000-4000)": 2000 <= summary["eb_max"] <= 4000,
        "eb_avg ~1205x (band 800-1900)": 800 <= summary["eb_avg"] <= 1900,
        "eb/tm ~15x (band 10-22)": 10 <= summary["eb_over_tm_avg"] <= 22,
        "gpu mixed vs baseline (obs. 4)": any(r["gpu_speedup"] < 1 for r in rows)
        and any(r["gpu_speedup"] > 1 for r in rows),
    }
    return {"rows": rows, "summary": summary, "checks": checks}


def main() -> int:
    out = run()
    print("\n== Fig. 7: latency improvement over Baseline-ePCM ==")
    print(f"{'network':8s} {'TacitMap-ePCM':>14s} {'EinsteinBarrier':>16s} {'GPU':>8s}")
    for r in out["rows"]:
        print(f"{r['network']:8s} {r['tm_speedup']:13.1f}x {r['eb_speedup']:15.1f}x "
              f"{r['gpu_speedup']:7.2f}x")
    s = out["summary"]
    print(f"\nTacitMap avg {s['tm_avg']:.0f}x (paper ~78x), max {s['tm_max']:.0f}x (paper ~154x)")
    print(f"EinsteinBarrier avg {s['eb_avg']:.0f}x (paper ~1205x), "
          f"max {s['eb_max']:.0f}x (paper ~3113x)")
    print(f"EB over TM avg {s['eb_over_tm_avg']:.1f}x (paper ~15x)")
    ok = True
    for name, passed in out["checks"].items():
        print(f"  [{'PASS' if passed else 'FAIL'}] {name}")
        ok &= passed
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
