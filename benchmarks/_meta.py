"""Shared provenance header for benchmark artifacts.

Every ``benchmarks/run.py --out`` JSON used to carry only its section
payloads — a BENCH_*.json from three PRs ago was indistinguishable from
today's except by file date, which breaks the whole point of keeping a
perf *trajectory*. :func:`bench_header` is the one place the provenance
stamp is spelled: schema version, UTC timestamp, jax/jaxlib versions,
the active backend and the git SHA (best-effort — absent git metadata
degrades to ``"unknown"``, never an exception inside a benchmark run).
"""

from __future__ import annotations

import datetime
import os
import subprocess

# bump when the {"smoke", "rc", "sections"} document shape changes
BENCH_SCHEMA_VERSION = 1


def _git_sha() -> str:
    # pin cwd to the repo (benchmarks may run from anywhere) and treat
    # ANY failure — no git binary, not a repo, detached worktree, odd
    # permissions — as "unknown": provenance is best-effort, a benchmark
    # run must never crash over missing git metadata
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


def bench_header() -> dict:
    """The provenance stamp ``run.py`` writes at the top of every
    ``--out`` document."""
    import jax

    try:
        backend = jax.default_backend()
    except Exception:  # no platform initialized (should not happen in CI)
        backend = "unknown"
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc
        ).isoformat(timespec="seconds"),
        "jax_version": jax.__version__,
        "jaxlib_version": getattr(
            __import__("jaxlib"), "__version__", "unknown"
        ),
        "backend": backend,
        "git_sha": _git_sha(),
    }
