"""Compiler one-call API smoke — the CI gate for ``repro.compiler``.

One :class:`repro.compiler.HardwareTarget` per registered execution
style, each run through the full ``compile -> prefill -> decode ->
serve`` round trip on the smoke LM and required to generate
byte-identically to the reference target: the one-call pipeline (map ->
program -> execute) must be semantically invisible, exactly like the
engines and K-grouping it wires together. Also exercises the
price-only path (``compile(cfg, None, target).price()``) so the DSE
seam can't silently rot.

    PYTHONPATH=src python -m benchmarks.compiler [--smoke]
"""

from __future__ import annotations

import dataclasses
import time


def roundtrip_sweep(targets, *, n_requests, prompt_len, gen):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import compiler as compiler_lib
    from repro.configs import get_smoke_config
    from repro.models import lm as lm_lib
    from repro.serving import Request

    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(1, cfg.vocab_size, (prompt_len,), dtype=np.int32)
        for _ in range(n_requests)
    ]
    batch_tokens = jnp.stack([jnp.asarray(p) for p in prompts])

    rows = []
    for target in targets:
        t0 = time.perf_counter()
        compiled = compiler_lib.compile(cfg, params, target)
        compile_s = time.perf_counter() - t0

        # direct drive: prefill + one decode step (graft the prompt KV
        # into a serving-capacity cache, same as launch/serve.py)
        logits, pre = compiled.prefill(batch_tokens)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        caches = compiled.graft_prefill_caches(
            compiled.init_cache(n_requests, prompt_len + gen + 2), pre
        )
        step_logits, _ = compiled.decode_step(
            first, jnp.asarray(prompt_len, jnp.int32), caches
        )
        second = jnp.argmax(step_logits, axis=-1)

        # serving drive: continuous batching through the same artifact
        se = compiled.serve(max_batch=2, max_len=prompt_len + gen + 2)
        for i, p in enumerate(prompts):
            se.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
        gens = {r.rid: tuple(r.generated) for r in se.run_to_completion()}

        rows.append({
            "target": target.describe(),
            "engine": target.engine,
            "policy": target.mapping_policy or "-",
            "k": se.group_k,
            "programmed": compiled.programmed,
            "compile_ms": compile_s * 1e3,
            "plan_tiles": compiled.plan.n_tiles if compiled.plan else None,
            "direct": [int(t) for t in first.tolist()] + [int(t) for t in second.tolist()],
            "gen": gens,
        })
    return rows


def run(smoke: bool = False) -> tuple[int, dict]:
    from repro.compiler import HardwareTarget
    from repro.core import engine as engine_lib

    targets = [
        HardwareTarget(),                                   # reference
        HardwareTarget(engine="wdm", group_size=2),         # native MMM
        HardwareTarget(engine="packed"),                    # Pallas kernel
        HardwareTarget(engine="tiled", mapping_policy="greedy"),  # plan-driven
    ]
    if not smoke:
        targets += [
            HardwareTarget(engine=name)
            for name in engine_lib.list_engines()
            if name not in {t.engine for t in targets}
        ]
        targets.append(HardwareTarget(engine="tiled", mapping_policy="greedy",
                                      prepare_weights=False))
    sizes = dict(n_requests=2, prompt_len=5, gen=3)
    rows = roundtrip_sweep(targets, **sizes)

    print("\n== compiler one-call round trip (compile -> prefill/decode/serve, "
          f"smoke LM, {sizes['n_requests']} requests) ==")
    print(f"{'engine':>14s} {'policy':>13s} {'K':>3s} {'progd':>6s} "
          f"{'tiles':>6s} {'compile_ms':>11s} {'exact':>6s}")
    ref = rows[0]
    exact = True
    for r in rows:
        ok = r["gen"] == ref["gen"] and r["direct"] == ref["direct"]
        exact &= ok
        tiles = "-" if r["plan_tiles"] is None else str(r["plan_tiles"])
        print(f"{r['engine']:>14s} {r['policy']:>13s} {r['k']:3d} "
              f"{r['programmed']:6d} {tiles:>6s} {r['compile_ms']:11.1f} "
              f"{str(ok):>6s}")
    print(f"bit-exact across the target grid: {exact}")

    # the price-only compilation the DSE sweep stands on
    from repro import compiler as compiler_lib
    from repro.configs import get_smoke_config

    price = compiler_lib.compile(
        get_smoke_config("qwen1.5-0.5b"), None,
        HardwareTarget(engine="tiled", mapping_policy="greedy"),
    ).price()
    print(price.summary())
    priced = price.n_tiles > 0 and price.latency_s > 0 and price.break_even_ticks > 0

    rc = 0 if (exact and priced) else 1
    payload = {
        "targets": [
            {k: v for k, v in r.items() if k not in ("gen", "direct")}
            for r in rows
        ],
        "bit_exact": exact,
        "price_only_ok": priced,
    }
    return rc, payload


def main(smoke: bool = False) -> int:
    return run(smoke=smoke)[0]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized grid")
    args = ap.parse_args()
    raise SystemExit(main(smoke=args.smoke))
