"""Fault-injection + fault-tolerance gates — ``BENCH_faults.json``.

PR 9's robustness story, exercised end to end on the smoke LM and
gated the way the scheduler sweep gates bit-exactness:

* **Gate (a) — null injection is free**: a ``FaultModel`` with
  fault_rate 0 wrapped around every crossbar backend produces
  bit-identical prefill logits and served generations to the plain
  engine. Injection must be a guaranteed no-op when nothing is broken.
* **Gate (b) — detection fires**: planted stuck cells are caught by the
  TacitMap complement-row consistency probe (``consistency_probe`` > 0
  on every corrupted artifact, == 0 on pristine ones) and ``locate``
  resolves them to the planted physical tiles.
* **Gate (c) — remap restores exactness**: whole-tile failures
  developing MID-SERVE are detected by the serving health monitor,
  quarantined, remapped onto spare tiles and every affected request is
  restarted — and every finished generation is byte-identical to the
  fault-free solo reference. Remap pricing (cells moved, reprogram
  energy/time) is reported through the costmodel seam.

    PYTHONPATH=src python -m benchmarks.faults [--smoke]
"""

from __future__ import annotations

import dataclasses

TICK_CAP = 2_000


def _bench_model():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import lm as lm_lib

    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def _prompts(n, lengths=(5, 9, 7, 4)):
    import numpy as np

    rng = np.random.default_rng(0)
    return [
        rng.integers(1, 1000, (lengths[i % len(lengths)],), dtype=np.int32)
        for i in range(n)
    ]


def _solo_refs(cm, prompts, gen, max_len):
    from repro.serving import Request

    refs = {}
    for i, p in enumerate(prompts):
        se = cm.serve(max_batch=1, max_len=max_len)
        st = se.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
        se.drain(TICK_CAP)
        refs[i] = tuple(st.generated)
    return refs


def null_injection_sweep(engines, prompts, gen, max_len):
    """Gate (a): fault_rate=0 wrapping is bit-identical everywhere."""
    import numpy as np

    from repro import compiler as compiler_lib
    from repro.compiler import HardwareTarget
    from repro.faults import FaultModel
    from repro.serving import Request

    cfg, params = _bench_model()
    toks = np.concatenate([prompts[0], prompts[1]])[None, :].astype(np.int32)
    rows = []
    for engine in engines:
        plain = compiler_lib.compile(cfg, params, HardwareTarget(engine=engine))
        wrapped = compiler_lib.compile(
            cfg, params, HardwareTarget(engine=engine, fault_model=FaultModel())
        )
        logits_ok = np.array_equal(
            np.asarray(plain.prefill(toks)[0]),
            np.asarray(wrapped.prefill(toks)[0]),
        )
        served_ok = True
        refs = _solo_refs(plain, prompts, gen, max_len)
        se = wrapped.serve(max_batch=2, max_len=max_len)
        sts = [
            se.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
            for i, p in enumerate(prompts)
        ]
        se.drain(TICK_CAP)
        for st in sts:
            if tuple(st.generated) != refs[st.rid]:
                served_ok = False
        rows.append({
            "engine": engine,
            "prefill_bit_exact": logits_ok,
            "served_bit_exact": served_ok,
        })
    return rows


def detection_sweep(rates, seeds):
    """Gate (b): planted stuck cells fire the consistency probe and
    locate to real physical tiles; pristine artifacts stay silent."""
    from repro import compiler as compiler_lib
    from repro.compiler import HardwareTarget
    from repro.faults import FaultModel

    cfg, params = _bench_model()
    rows = []
    for rate in rates:
        for seed in seeds:
            fm = FaultModel(
                seed=seed, stuck_set_rate=rate / 2, stuck_reset_rate=rate / 2
            )
            cm = compiler_lib.compile(
                cfg, params,
                HardwareTarget(engine="tacitmap", fault_model=fm),
            )
            eng = cm.engine
            arts = cm._fault_artifacts()
            probes = [float(eng.consistency_probe(pw).max()) for pw in arts]
            located = cm.scan_faults()
            corrupted = any(eng.locate(pw) for pw in arts)
            rows.append({
                "rate": rate,
                "seed": seed,
                "n_artifacts": len(arts),
                "probe_max": max(probes),
                "probe_fired": any(p > 0 for p in probes),
                "tiles_located": len(located.tiles),
                "corrupted": corrupted,
                # rate 0 must stay silent; nonzero rates at these sizes
                # essentially always corrupt something AND the probe
                # must fire whenever locate found corruption
                "detected_ok": (
                    (not corrupted and not any(p > 0 for p in probes))
                    if rate == 0.0
                    else (corrupted and any(p > 0 for p in probes))
                ),
            })
    return rows


def remap_sweep(prompts, gen, max_len, *, spare_tiles, fail_after):
    """Gate (c): whole-tile failures mid-serve -> monitor detects,
    remaps onto spares, restarts in-flight — generations stay solo-exact."""
    from repro import compiler as compiler_lib
    from repro.compiler import HardwareTarget
    from repro.faults import FaultModel
    from repro.serving import Request, RequestStatus

    cfg, params = _bench_model()
    clean = HardwareTarget(
        engine="tiled", mapping_policy="tacitmap", spare_tiles=spare_tiles
    )
    cm_ref = compiler_lib.compile(cfg, params, clean)
    refs = _solo_refs(cm_ref, prompts, gen, max_len)

    # resolved tiles: the wrapper sees per-shape (first-instance)
    # placements, so plant failures on tiles it actually executes
    cm = compiler_lib.compile(
        cfg, params, dataclasses.replace(clean, fault_model=FaultModel())
    )
    resolved = sorted({
        t for pw in cm._fault_artifacts()
        for *_, t in cm.engine._placement_blocks(pw.m, pw.n)
    })
    victim = resolved[0]

    se = cm.serve(max_batch=len(prompts), max_len=max_len)
    sts = [
        se.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
        for i, p in enumerate(prompts)
    ]
    ticks = 0
    failed_at = None
    while not se.idle() and ticks <= TICK_CAP:
        if ticks == fail_after:
            cm.engine.fail_tile(victim)
            cm.refresh_faults()
            se._rebind()
            failed_at = ticks
        se.step()
        ticks += 1

    exact = all(
        st.status is RequestStatus.FINISHED
        and tuple(st.generated) == refs[st.rid]
        for st in sts
    )
    moves = len(cm.plan.avoid_tiles)
    s = se.stats()
    return {
        "spare_tiles": spare_tiles,
        "victim_tile": victim,
        "failed_at_tick": failed_at,
        "ticks": ticks,
        "remaps": se.health.remaps,
        "degraded": se.health.degraded,
        "restarted": s.scheduler.restarted,
        "quarantined": sorted(se.health.quarantined),
        "avoided_tiles": moves,
        "spares_left": len(cm.plan.spares),
        "post_remap_sweep_clean": not cm.scan_faults().tiles,
        "bit_exact_vs_solo": exact,
        "drained": ticks <= TICK_CAP,
    }


def remap_pricing(spare_tiles=3):
    """The costmodel seam: what one whole-tile remap costs to reprogram
    vs programming the full plan from scratch."""
    from repro import compiler as compiler_lib
    from repro.compiler import HardwareTarget
    from repro.core import costmodel
    from repro.faults import FaultModel, FaultMap

    cfg, params = _bench_model()
    cm = compiler_lib.compile(
        cfg, params,
        HardwareTarget(
            engine="tiled", mapping_policy="tacitmap",
            spare_tiles=spare_tiles, fault_model=FaultModel(),
        ),
    )
    full = costmodel.plan_programming_cost(cm.plan)
    resolved = sorted({
        t for pw in cm._fault_artifacts()
        for *_, t in cm.engine._placement_blocks(pw.m, pw.n)
    })
    cm.engine.fail_tile(resolved[0])
    report = cm.remap(FaultMap(tiles=[resolved[0]]))
    return {
        "full_program_cells": full.cells,
        "full_program_uj": full.energy_pj * 1e-6,
        "full_program_us": full.time_ns * 1e-3,
        "remap_moves": len(report.moves),
        "remap_cells": report.cost.cells,
        "remap_uj": report.cost.energy_pj * 1e-6,
        "remap_us": report.cost.time_ns * 1e-3,
        "incremental_fraction": report.cost.cells / max(full.cells, 1),
    }


def run(smoke: bool = False) -> tuple[int, dict]:
    if smoke:
        engines = ("tacitmap", "wdm", "tiled")
        n_requests, gen = 3, 5
        rates, seeds = (0.0, 0.02), (3,)
        remap_cases = (dict(spare_tiles=3, fail_after=2),)
    else:
        engines = ("tacitmap", "wdm", "packed", "tiled", "custbinarymap")
        n_requests, gen = 4, 8
        rates, seeds = (0.0, 0.005, 0.02, 0.1), (3, 7)
        remap_cases = (
            dict(spare_tiles=2, fail_after=1),
            dict(spare_tiles=3, fail_after=2),
            dict(spare_tiles=4, fail_after=4),
        )

    prompts = _prompts(n_requests)
    max_len = max(len(p) for p in prompts) + gen + 2

    null_rows = null_injection_sweep(engines, prompts, gen, max_len)
    print("\n== gate (a): null fault model is bit-identical ==")
    print(f"{'engine':>14s} {'prefill':>8s} {'served':>7s}")
    for r in null_rows:
        print(f"{r['engine']:>14s} {str(r['prefill_bit_exact']):>8s} "
              f"{str(r['served_bit_exact']):>7s}")
    null_ok = all(
        r["prefill_bit_exact"] and r["served_bit_exact"] for r in null_rows
    )

    det_rows = detection_sweep(rates, seeds)
    print("\n== gate (b): planted stuck cells fire the consistency probe ==")
    print(f"{'rate':>6s} {'seed':>5s} {'probe_max':>10s} {'tiles':>6s} "
          f"{'ok':>4s}")
    for r in det_rows:
        print(f"{r['rate']:6.3f} {r['seed']:5d} {r['probe_max']:10.1f} "
              f"{r['tiles_located']:6d} {str(r['detected_ok']):>4s}")
    det_ok = all(r["detected_ok"] for r in det_rows)

    remap_rows = [
        remap_sweep(prompts, gen, max_len, **case) for case in remap_cases
    ]
    print("\n== gate (c): mid-serve tile failure -> remap -> solo-exact ==")
    print(f"{'spares':>7s} {'victim':>7s} {'remaps':>7s} {'restart':>8s} "
          f"{'clean':>6s} {'exact':>6s}")
    for r in remap_rows:
        print(f"{r['spare_tiles']:7d} {r['victim_tile']:7d} {r['remaps']:7d} "
              f"{r['restarted']:8d} {str(r['post_remap_sweep_clean']):>6s} "
              f"{str(r['bit_exact_vs_solo']):>6s}")
    remap_ok = all(
        r["bit_exact_vs_solo"] and r["post_remap_sweep_clean"]
        and r["remaps"] >= 1 and not r["degraded"] and r["drained"]
        for r in remap_rows
    )

    pricing = remap_pricing()
    print("\n== remap reprogramming cost (costmodel seam) ==")
    print(f"full program: {pricing['full_program_cells']} cells / "
          f"{pricing['full_program_uj']:.2f} uJ / "
          f"{pricing['full_program_us']:.1f} us")
    print(f"one-tile remap: {pricing['remap_cells']} cells / "
          f"{pricing['remap_uj']:.2f} uJ / {pricing['remap_us']:.1f} us "
          f"({pricing['incremental_fraction']:.1%} of a full reprogram)")

    print(f"\nnull injection bit-identical: {null_ok}")
    print(f"detection fires on planted faults: {det_ok}")
    print(f"post-remap generations solo-exact: {remap_ok}")

    rc = 0 if (null_ok and det_ok and remap_ok) else 1
    payload = {
        "null_injection": null_rows,
        "detection": det_rows,
        "remap": remap_rows,
        "pricing": pricing,
        "null_bit_exact": null_ok,
        "detection_ok": det_ok,
        "remap_bit_exact": remap_ok,
    }
    return rc, payload


def main(smoke: bool = False) -> int:
    return run(smoke=smoke)[0]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweep")
    raise SystemExit(main(smoke=ap.parse_args().smoke))
