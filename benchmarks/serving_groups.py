"""Serving K-group batching sweep — hardware targets across K × engine.

Two views of the same refactor (serving/engine.py BatchPlanner):

* **Measured**: one :class:`repro.compiler.HardwareTarget` per
  (engine, K), compiled and served (``compile(...).serve(...)``) on the
  smoke LM. Reports the decode tick cost in crossbar terms — K-groups
  issued (one ``binary_mmm`` per projection per tick) vs slot-at-a-time
  steps — plus ragged-tail idle lanes and directional CPU tok/s. The
  `wdm` engine's group count drops ~K× vs K=1 (PR-1 slot-at-a-time
  decode) while every engine stays bit-exact: the sweep fails if any
  target's generation diverges from the reference target's.
* **Modeled**: cost-model ``grouped_decode_tick`` latency/energy across
  K for EinsteinBarrier vs TacitMap-ePCM — the paper's K-way latency
  division showing up in serving-tick numbers.

    PYTHONPATH=src python -m benchmarks.serving_groups [--smoke] \
        [--engine wdm] [--group-size 4]
"""

from __future__ import annotations

import dataclasses
import time


def measured_sweep(targets, *, max_batch, n_requests, prompt_len, gen):
    import jax
    import numpy as np

    from repro import compiler as compiler_lib
    from repro.configs import get_smoke_config
    from repro.models import lm as lm_lib
    from repro.serving import Request

    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
    params = lm_lib.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(1, cfg.vocab_size, (prompt_len,), dtype=np.int32)
        for _ in range(n_requests)
    ]

    rows = []
    for target in targets:
        se = compiler_lib.compile(cfg, params, target).serve(
            max_batch=max_batch, max_len=prompt_len + gen + 2
        )
        for i, p in enumerate(prompts):
            se.submit(Request(rid=i, prompt=p, max_new_tokens=gen))
        t0 = time.perf_counter()
        done = se.run_to_completion()
        wall = time.perf_counter() - t0
        s = se.stats()
        rows.append({
            "engine": target.engine,
            "k": se.group_k,
            "ticks": s.ticks,
            "decoded": s.decoded,
            "mmm_groups": s.mmm_groups,
            # a measured MMM reduction only exists when a registry
            # backend executed (reference serves plain jnp: no calls)
            "reduction": (
                s.decoded / s.mmm_groups if s.mmm_groups else None
            ),
            "pad_lanes": s.pad_lanes,
            "tok_s": s.decoded / max(wall, 1e-9),
            "gen": {r.rid: tuple(r.generated) for r in done},
        })
    return rows


def modeled_sweep(ks):
    from repro.core import costmodel as cm
    from repro.core.networks import LayerDesc

    layer = LayerDesc(name="fc", m=512, n=512, positions=1, binary=True)
    out = {}
    for p in (cm.EINSTEINBARRIER, cm.TACITMAP_EPCM):
        out[p.name] = cm.grouped_decode_sweep(p, layer, n_active=16, ks=ks)
    return layer, out


def main(smoke: bool = False, engines=None, ks=None) -> int:
    from repro.compiler import HardwareTarget
    from repro.core import engine as engine_lib

    if smoke:
        # two full waves through the pool: the K=1 vs K=4 comparison is
        # clean (~K x); ragged tails are exercised by the full mode and
        # tests/test_serving_groups.py
        engines = engines or ("reference", "wdm", "packed")
        ks = ks or (1, 4)
        sizes = dict(max_batch=4, n_requests=8, prompt_len=6, gen=3)
    else:
        engines = engines or tuple(engine_lib.list_engines())
        ks = ks or (1, 2, 4)
        sizes = dict(max_batch=4, n_requests=6, prompt_len=8, gen=6)

    # the sweep axis IS the target: one HardwareTarget per (engine, K)
    targets = [
        HardwareTarget(engine=name, group_size=k)
        for name in engines for k in ks
    ]
    rows = measured_sweep(targets, **sizes)

    print("\n== serving K-group sweep (measured, smoke LM, "
          f"batch={sizes['max_batch']}, {sizes['n_requests']} requests) ==")
    print(f"{'engine':>14s} {'K':>3s} {'ticks':>6s} {'decoded':>8s} "
          f"{'K-groups':>9s} {'reduction':>9s} {'idle':>5s} {'tok/s':>8s}")
    for r in rows:
        red = f"{r['reduction']:8.1f}x" if r["reduction"] else f"{'-':>9s}"
        print(f"{r['engine']:>14s} {r['k']:3d} {r['ticks']:6d} {r['decoded']:8d} "
              f"{r['mmm_groups']:9d} {red} {r['pad_lanes']:5d} "
              f"{r['tok_s']:8.1f}")

    # bit-exactness across the whole target grid: K-grouping and
    # backends are semantically invisible (the registry's contract,
    # served end-to-end through the one-call pipeline)
    gens = {(r["engine"], r["k"]): r["gen"] for r in rows}
    ref = next(iter(gens.values()))
    exact = all(g == ref for g in gens.values())

    # the headline: wdm's decode tick count (K-groups) drops ~K× vs the
    # PR-1 slot-at-a-time decode (K=1)
    wdm = {r["k"]: r for r in rows if r["engine"] == "wdm"}
    k_win = True
    if wdm and len(wdm) > 1:
        k_max = max(wdm)
        got = wdm[1]["mmm_groups"] / wdm[k_max]["mmm_groups"]
        print(f"wdm decode tick count: {wdm[1]['mmm_groups']} (K=1, slot-at-a-time) "
              f"-> {wdm[k_max]['mmm_groups']} (K={k_max}): {got:.1f}x reduction")
        k_win = got > k_max / 2  # ragged tails keep it under K
    print(f"bit-exact across K x engine grid: {exact}")

    layer, modeled = modeled_sweep(ks=(1, 2, 4, 8, 16))
    print(f"\n== modeled grouped decode tick ({layer.m}x{layer.n} FC, 16 active slots) ==")
    print(f"{'design':>16s} {'K':>3s} {'groups':>7s} {'latency_ns':>11s} "
          f"{'energy_pJ':>10s} {'speedup':>8s}")
    for design, ticks in modeled.items():
        for t in ticks:
            print(f"{design:>16s} {t.k:3d} {t.groups:7d} {t.latency_ns:11.0f} "
                  f"{t.energy_pj:10.1f} {t.speedup:7.1f}x")
    print("(EinsteinBarrier divides tick latency by K — Eq. 2/3 overheads are in "
          "the energy column; electrical designs are K-invariant)")
    return 0 if (exact and k_win) else 1


if __name__ == "__main__":
    import argparse

    from repro.compiler import add_target_args, target_from_args

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweep")
    # shared target flags; --engine/--group-size restrict the sweep axes
    add_target_args(ap, default_engine=None)
    args = ap.parse_args()
    try:
        tgt = target_from_args(args)
    except Exception as e:
        ap.error(str(e))
    # no silent knob drops: the flags this sweep does not consume are
    # rejected, not accepted-and-ignored
    if tgt.wants_plan or not tgt.prepare_weights:
        ap.error("--mapping-policy/--tile-budget/--raw-weights do not apply: "
                 "this sweep grids engine x K with prepared weights")
    raise SystemExit(main(
        smoke=args.smoke,
        engines=(tgt.engine,) if args.engine else None,
        ks=(tgt.group_size,) if tgt.group_size else None,
    ))
