"""Benchmark driver — one section per paper table/figure plus the
framework-level reports.

    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --sections kernel_bench,wdm_sweep

Sections:
  1. paper_latency  — Fig. 7 (latency, 4 designs x 6 BNNs) + band checks
  2. paper_energy   — Fig. 8 (energy) + band checks
  3. kernel_bench   — packed XNOR matmul traffic/exactness + a uniform
                      sweep over every backend in the engine registry
  4. wdm_sweep      — WDM capacity K sweep (Eq. 2/3 overheads vs
                      step-count win — the paper's §IV-B trade-off)
  5. multilevel     — multi-level PCM cells vs noise (§VI-C future work)
  6. dse            — target-grid DSE: mapping policy x tile budget x
                      WDM K priced through CompiledModel.price()
                      (latency-vs-area pareto, §VI-C future work)
  7. roofline       — §Roofline table from dry-run artifacts (if present)
  8. serving_groups — serving K-group batched decode throughput sweep
                      (K x engine, measured + modeled)

  9. mapping        — mapping-compiler sweep: allocator policy x engine
                      (plan pricing, tiled parity, serving round-trip)
 10. serving_latency — prepared-vs-unprepared decode tick wall time per
                      engine x K + modeled one-time programming cost
                      (the serving-latency perf-trajectory point)
 11. compiler       — one-call hardware-compilation round trip
                      (compile -> prefill/decode/serve bit-exactness
                      per target + the price-only DSE seam)
 12. kernels        — fused decode-tick kernel gate: fused vs unfused
                      packed wall time (kernel level + serving ticks)
                      with bit-exactness required at both levels
 13. scheduler      — request-scheduler offered-load sweep (arrival rate
                      x K x engine): throughput/TTFT/rejection, gated on
                      bit-exactness vs solo references and on draining
                      without admission deadlock (``BENCH_scheduler.json``)
 14. obs           — telemetry gate: traced serving with measured-vs-
                      modeled decode-tick pricing (ratio finite per
                      engine x K), tracing-on/off bit-exactness, the
                      disabled-path overhead bound, and a sample Chrome
                      trace artifact (``BENCH_obs.json`` + trace.json)
 15. faults        — fault-injection gate: null fault model bit-identical
                      per engine, planted stuck cells fire the consistency
                      probe, mid-serve tile failure -> health-monitor
                      remap onto spares with solo-exact generations +
                      modeled remap cost (``BENCH_faults.json``)
 16. fleet         — fleet-serving gate: routed == solo bit-exact across
                      policy x replica count x engine, prefix routing's
                      hit rate and prefill saving strictly beat
                      round-robin on a shared-prefix workload, and a
                      mid-serve replica degrade fails over with zero
                      fleet-wide FAILED (``BENCH_fleet.json``)

``--sections engines`` is an alias for the engine-registry gate
(kernel_bench + serving_groups); ``--smoke`` shrinks those sections to
CI-sized work. ``--out PATH`` writes the structured section results as
JSON (sections that only print report their exit code), so CI keeps the
perf trajectory as an artifact (``BENCH_mapping.json``,
``BENCH_serving.json``, and the DSE target grid ``BENCH_dse.json``).
"""

from __future__ import annotations

import argparse

SECTIONS = (
    "paper_latency",
    "paper_energy",
    "kernel_bench",
    "wdm_sweep",
    "multilevel",
    "dse",
    "roofline",
    "serving_groups",
    "mapping",
    "serving_latency",
    "compiler",
    "kernels",
    "scheduler",
    "obs",
    "faults",
    "fleet",
)

ALIASES = {"engines": {"kernel_bench", "serving_groups"}}


def wdm_sweep() -> int:
    import dataclasses

    from repro.core import costmodel as cm
    from repro.core.networks import NETWORKS

    print("\n== WDM capacity sweep (EinsteinBarrier, CNN-M) ==")
    print(f"{'K':>4s} {'latency_us/img':>15s} {'energy_uJ/img':>14s} {'tx_power_mW':>12s}")
    net = NETWORKS["CNN-M"]
    for k in (1, 2, 4, 8, 16, 32):
        tile = dataclasses.replace(cm.EINSTEINBARRIER.tile, wdm_k=k)
        p = dataclasses.replace(cm.EINSTEINBARRIER, tile=tile)
        lat = cm.network_latency_s(p, net) * 1e6
        en = cm.network_energy_j(p, net) * 1e6
        tx = cm.transmitter_power_mw(p)
        print(f"{k:4d} {lat:15.2f} {en:14.3f} {tx:12.0f}")
    print("(K=16 is the paper's technology limit [13]; transmitter power grows "
          "~linearly in K*M — Eq. 3)")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="Run the paper-reproduction benchmark sections "
        "(latency/energy bands, kernel + engine-registry sweeps, DSE).",
    )
    ap.add_argument(
        "--sections",
        default="all",
        help="comma-separated subset of: " + ", ".join(SECTIONS)
        + ", or the alias 'engines' (= kernel_bench,serving_groups); default: all",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI-sized work: shrink the kernel/serving sweeps",
    )
    ap.add_argument(
        "--out",
        default=None,
        metavar="PATH",
        help="write section results as JSON (e.g. BENCH_mapping.json) — "
        "structured rows where a section provides them, exit codes otherwise",
    )
    ap.add_argument(
        "--list-sections",
        action="store_true",
        help="print the known section names (one per line) and exit",
    )
    args = ap.parse_args(argv)
    if args.list_sections:
        for s in SECTIONS:
            print(s)
        for alias, expansion in ALIASES.items():
            print(f"{alias} (= {','.join(sorted(expansion))})")
        return 0
    wanted = set(SECTIONS) if args.sections == "all" else {
        s.strip() for s in args.sections.split(",") if s.strip()
    }
    for alias, expansion in ALIASES.items():
        if alias in wanted:
            wanted = (wanted - {alias}) | expansion
    unknown = wanted - set(SECTIONS)
    if unknown:
        # fail fast WITH the menu: a typo'd section name should not cost
        # a benchmark run to discover the spelling
        ap.error(
            f"unknown sections: {', '.join(sorted(unknown))}; "
            f"known: {', '.join(SECTIONS)}, "
            f"aliases: {', '.join(sorted(ALIASES))}"
        )

    import glob
    import json

    from benchmarks import (
        compiler,
        dse,
        kernel_bench,
        kernels_fused,
        mapping,
        multilevel,
        paper_energy,
        paper_latency,
        roofline,
        scheduler,
        serving_groups,
        serving_latency,
    )
    # aliased: `obs` unqualified would shadow repro.obs at call sites
    from benchmarks import obs as obs_bench
    # aliased: keep the section import style uniform with repro.faults
    from benchmarks import faults as faults_bench
    # aliased: keep the section import style uniform with repro.fleet
    from benchmarks import fleet as fleet_bench

    rc = 0
    results: dict[str, dict] = {}

    def record(section: str, section_rc: int, payload: dict | None = None) -> int:
        results[section] = dict(payload or {}, rc=section_rc)
        return section_rc

    if "paper_latency" in wanted:
        rc |= record("paper_latency", paper_latency.main())
    if "paper_energy" in wanted:
        rc |= record("paper_energy", paper_energy.main())
    if "kernel_bench" in wanted:
        rc |= record("kernel_bench", kernel_bench.main(smoke=args.smoke))
    if "wdm_sweep" in wanted:
        rc |= record("wdm_sweep", wdm_sweep())
    if "multilevel" in wanted:
        rc |= record("multilevel", multilevel.main())
    if "dse" in wanted:
        d_rc, payload = dse.run(smoke=args.smoke)
        rc |= record("dse", d_rc, payload)
    if "roofline" in wanted:
        if glob.glob("runs/dryrun/*.json"):
            rc |= record("roofline", roofline.main())
        else:
            print("\n[roofline] skipped — no runs/dryrun/*.json (run repro.launch.dryrun)")
    if "serving_groups" in wanted:
        rc |= record("serving_groups", serving_groups.main(smoke=args.smoke))
    if "mapping" in wanted:
        m_rc, payload = mapping.run(smoke=args.smoke)
        rc |= record("mapping", m_rc, payload)
    if "serving_latency" in wanted:
        s_rc, payload = serving_latency.run(smoke=args.smoke)
        rc |= record("serving_latency", s_rc, payload)
    if "compiler" in wanted:
        c_rc, payload = compiler.run(smoke=args.smoke)
        rc |= record("compiler", c_rc, payload)
    if "kernels" in wanted:
        k_rc, payload = kernels_fused.run(smoke=args.smoke)
        rc |= record("kernels", k_rc, payload)
    if "scheduler" in wanted:
        sc_rc, payload = scheduler.run(smoke=args.smoke)
        rc |= record("scheduler", sc_rc, payload)
    if "obs" in wanted:
        o_rc, payload = obs_bench.run(smoke=args.smoke)
        rc |= record("obs", o_rc, payload)
    if "faults" in wanted:
        f_rc, payload = faults_bench.run(smoke=args.smoke)
        rc |= record("faults", f_rc, payload)
    if "fleet" in wanted:
        fl_rc, payload = fleet_bench.run(smoke=args.smoke)
        rc |= record("fleet", fl_rc, payload)

    if args.out:
        from benchmarks._meta import bench_header

        doc = {
            "header": bench_header(),
            "smoke": args.smoke,
            "rc": rc,
            "sections": results,
        }
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2, default=str)
        print(f"\n[run] wrote section results to {args.out}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
