"""Benchmark driver — one section per paper table/figure plus the
framework-level reports.

    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --sections kernel_bench,wdm_sweep

Sections:
  1. paper_latency  — Fig. 7 (latency, 4 designs x 6 BNNs) + band checks
  2. paper_energy   — Fig. 8 (energy) + band checks
  3. kernel_bench   — packed XNOR matmul traffic/exactness + a uniform
                      sweep over every backend in the engine registry
  4. wdm_sweep      — WDM capacity K sweep (Eq. 2/3 overheads vs
                      step-count win — the paper's §IV-B trade-off)
  5. multilevel     — multi-level PCM cells vs noise (§VI-C future work)
  6. dse            — oPCM VCore design-space pareto (§VI-C future work)
  7. roofline       — §Roofline table from dry-run artifacts (if present)
  8. serving_groups — serving K-group batched decode throughput sweep
                      (K x engine, measured + modeled)

``--sections engines`` is an alias for the engine-registry gate
(kernel_bench + serving_groups); ``--smoke`` shrinks those sections to
CI-sized work.
"""

from __future__ import annotations

import argparse

SECTIONS = (
    "paper_latency",
    "paper_energy",
    "kernel_bench",
    "wdm_sweep",
    "multilevel",
    "dse",
    "roofline",
    "serving_groups",
)

ALIASES = {"engines": {"kernel_bench", "serving_groups"}}


def wdm_sweep() -> int:
    import dataclasses

    from repro.core import costmodel as cm
    from repro.core.networks import NETWORKS

    print("\n== WDM capacity sweep (EinsteinBarrier, CNN-M) ==")
    print(f"{'K':>4s} {'latency_us/img':>15s} {'energy_uJ/img':>14s} {'tx_power_mW':>12s}")
    net = NETWORKS["CNN-M"]
    for k in (1, 2, 4, 8, 16, 32):
        tile = dataclasses.replace(cm.EINSTEINBARRIER.tile, wdm_k=k)
        p = dataclasses.replace(cm.EINSTEINBARRIER, tile=tile)
        lat = cm.network_latency_s(p, net) * 1e6
        en = cm.network_energy_j(p, net) * 1e6
        tx = cm.transmitter_power_mw(p)
        print(f"{k:4d} {lat:15.2f} {en:14.3f} {tx:12.0f}")
    print("(K=16 is the paper's technology limit [13]; transmitter power grows "
          "~linearly in K*M — Eq. 3)")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="Run the paper-reproduction benchmark sections "
        "(latency/energy bands, kernel + engine-registry sweeps, DSE).",
    )
    ap.add_argument(
        "--sections",
        default="all",
        help="comma-separated subset of: " + ", ".join(SECTIONS)
        + ", or the alias 'engines' (= kernel_bench,serving_groups); default: all",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI-sized work: shrink the kernel/serving sweeps",
    )
    args = ap.parse_args(argv)
    wanted = set(SECTIONS) if args.sections == "all" else {
        s.strip() for s in args.sections.split(",") if s.strip()
    }
    for alias, expansion in ALIASES.items():
        if alias in wanted:
            wanted = (wanted - {alias}) | expansion
    unknown = wanted - set(SECTIONS)
    if unknown:
        ap.error(f"unknown sections: {', '.join(sorted(unknown))}")

    import glob

    from benchmarks import (
        dse,
        kernel_bench,
        multilevel,
        paper_energy,
        paper_latency,
        roofline,
        serving_groups,
    )

    rc = 0
    if "paper_latency" in wanted:
        rc |= paper_latency.main()
    if "paper_energy" in wanted:
        rc |= paper_energy.main()
    if "kernel_bench" in wanted:
        rc |= kernel_bench.main(smoke=args.smoke)
    if "wdm_sweep" in wanted:
        rc |= wdm_sweep()
    if "multilevel" in wanted:
        rc |= multilevel.main()
    if "dse" in wanted:
        rc |= dse.main()
    if "roofline" in wanted:
        if glob.glob("runs/dryrun/*.json"):
            rc |= roofline.main()
        else:
            print("\n[roofline] skipped — no runs/dryrun/*.json (run repro.launch.dryrun)")
    if "serving_groups" in wanted:
        rc |= serving_groups.main(smoke=args.smoke)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
