"""Multi-level PCM sweep (paper §VI-C future work, quantified).

Reproduces the §II-C robustness argument with the device models: binary
cells tolerate the oPCM noise regime; multi-level cells trade density/
latency for MAC errors that grow fast with depth and noise.

    PYTHONPATH=src python -m benchmarks.multilevel
"""

from __future__ import annotations

from repro.core.multilevel import sweep


def main() -> int:
    points = sweep()
    print("\n== multi-level oPCM cells: MAC error vs depth/noise ==")
    print(f"{'bits':>5s} {'sigma':>7s} {'MAC err':>9s} {'density':>8s} {'latency win':>11s}")
    by_bits: dict[int, list] = {}
    for p in points:
        by_bits.setdefault(p.bits, []).append(p)
        print(f"{p.bits:5d} {p.sigma:7.3f} {p.error_rate:9.4f} {p.density_x:7.0f}x "
              f"{p.latency_x:10.0f}x")
    # the paper's design point: binary stays exact where deeper cells break
    ok = True
    bin_low = [p for p in by_bits[1] if p.sigma <= 0.02]
    multi_high = [p for p in by_bits.get(4, []) if p.sigma >= 0.05]
    checks = {
        "binary exact at realistic noise (sigma<=0.02)": all(
            p.error_rate == 0.0 for p in bin_low
        ),
        "4-bit cells degrade at high noise (err>5%)": all(
            p.error_rate > 0.05 for p in multi_high
        ),
        "error monotone in depth at sigma=0.05": (
            by_bits[1][-2].error_rate <= by_bits[2][-2].error_rate <= by_bits[4][-2].error_rate
        ),
    }
    for name, passed in checks.items():
        print(f"  [{'PASS' if passed else 'FAIL'}] {name}")
        ok &= passed
    print("(why EinsteinBarrier stays binary — §II-C / [16])")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
