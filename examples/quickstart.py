"""Quickstart: the paper's pipeline end to end in ~60 lines.

1. Take a binary weight/input pair and show Eq. 1: XNOR+Popcount equals
   the TacitMap complement-VMM (what the crossbar computes in 1 step).
2. Map a small BNN layer with TacitMap and with CustBinaryMap [15]:
   same results, n-times fewer crossbar steps.
3. Turn on WDM (EinsteinBarrier): K input vectors per step.
4. Run the same mapping through the Pallas TPU kernel path (bit-packed
   XNOR matmul) — the TPU-native translation of the same idea.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import bnn, custbinarymap, tacitmap, wdm
from repro.core.crossbar import EPCM_TILE, OPCM_TILE
from repro.kernels import ops

key = jax.random.key(0)
k1, k2 = jax.random.split(key)

# -- 1. Eq. 1 ---------------------------------------------------------------
m, n, batch = 96, 32, 8
a_bits = jax.random.bernoulli(k1, 0.5, (batch, m)).astype(jnp.uint32)
w_bits = jax.random.bernoulli(k2, 0.5, (m, n)).astype(jnp.uint32)

# digital reference: per (input, output-column) XNOR then popcount
xnor_pc = bnn.popcount(bnn.xnor(a_bits[:, None, :], w_bits.T[None, :, :]))
vmm = bnn.tacitmap_vmm(a_bits, w_bits)               # [a; ā] @ [w; w̄]
assert jnp.array_equal(xnor_pc, vmm)
print(f"Eq. 1 holds: popcount(XNOR) == complement-VMM for all {batch}x{n} outputs")

# -- 2. TacitMap vs CustBinaryMap at the crossbar level ----------------------
tm_layer = tacitmap.map_weights(w_bits, EPCM_TILE)
tm_out = tacitmap.apply(tm_layer, a_bits)
tm_steps = tacitmap.steps_for(m, n, batch, EPCM_TILE)

cbm_layer = custbinarymap.map_weights(w_bits, EPCM_TILE)
cbm_out = custbinarymap.apply(cbm_layer, a_bits)
cbm_steps = custbinarymap.steps_for(m, n, batch, EPCM_TILE)

assert jnp.array_equal(tm_out, cbm_out), "mappings must agree bit-exactly"
print(f"TacitMap: {tm_steps} crossbar steps; CustBinaryMap: {cbm_steps} "
      f"({cbm_steps / tm_steps:.0f}x more — the paper's n-times law)")

# -- 3. WDM (EinsteinBarrier) -------------------------------------------------
tm_opcm = tacitmap.map_weights(w_bits, OPCM_TILE)
wdm_out = wdm.wdm_apply(tm_opcm, a_bits)
assert jnp.array_equal(wdm_out, tm_out)
wdm_steps = wdm.steps_for(batch, OPCM_TILE.wdm_k)
print(f"WDM K={OPCM_TILE.wdm_k}: {wdm_steps} step(s) for the same {batch} inputs "
      f"({tm_steps / wdm_steps:.0f}x fewer than TacitMap-ePCM)")

# -- 4. TPU-native path (Pallas kernel, bit-packed) ---------------------------
# (int32 first: 2*b-1 on uint32 would wrap -1 to 2^32-1)
a_signs = bnn.bits_to_signs(a_bits.astype(jnp.int32)).astype(jnp.float32)
w_signs = bnn.bits_to_signs(w_bits.astype(jnp.int32)).astype(jnp.float32)
dot = ops.xnor_matmul(a_signs, w_signs)              # int32 ±1 dot products
expected = 2 * xnor_pc.astype(jnp.int32) - m         # Eq. 1 affine
assert jnp.array_equal(dot, expected)
print(f"Pallas packed kernel matches: ±1 dot == 2*popcount - m "
      f"(32 weights per int32 lane, 16x less HBM than bf16)")
print("quickstart OK")
