"""The paper's technique as a first-class LM feature: train a small LM
with hidden projections binarized (``quant="bnn"`` — BitLinear with STE,
first/last layers high-precision per §II-B), then serve it with batched
prefill+decode.

This is what "TacitMap for transformers" means in this framework: every
hidden matmul becomes an XNOR+popcount surface that the EinsteinBarrier
mapping (or the packed Pallas kernel on TPU) can execute.

    PYTHONPATH=src python examples/serve_bnn_lm.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import lm_batch
from repro.models import lm as lm_lib
from repro.optim import OptConfig, adamw_init, adamw_update

STEPS, B, S, GEN = 60, 8, 64, 12

cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), quant="bnn")
fp_cfg = dataclasses.replace(cfg, quant="none")
print(f"model: {cfg.name} quant={cfg.quant} ({cfg.param_count()/1e6:.2f}M params)")

params = lm_lib.init_params(jax.random.key(0), cfg)
opt_cfg = OptConfig(weight_decay=0.0)
opt = adamw_init(params, opt_cfg)


@jax.jit
def step(params, opt, batch):
    loss, grads = jax.value_and_grad(lambda p: lm_lib.loss_fn(p, batch, cfg))(params)
    params, opt = adamw_update(grads, params, opt, 1e-3, opt_cfg)
    return params, opt, loss


t0 = time.time()
first = last = None
for i in range(STEPS):
    params, opt, loss = step(params, opt, lm_batch(cfg, B, S, step=i))
    first = first if first is not None else float(loss)
    last = float(loss)
print(f"trained {STEPS} steps in {time.time()-t0:.1f}s; "
      f"loss {first:.3f} -> {last:.3f} (binarized hidden projections, STE)")

# -- batched serving ---------------------------------------------------------
prompts = lm_batch(cfg, B, 16, step=999)["tokens"]
logits, pre = jax.jit(lambda p, t: lm_lib.prefill(p, t, cfg))(params, prompts)
caches = lm_lib.init_cache(cfg, B, 16 + GEN)
caches = jax.tree.map(
    lambda d, s: d.at[:, :, : s.shape[2]].set(s.astype(d.dtype)) if d.ndim == 5 else s,
    caches, pre,
)
decode = jax.jit(lambda p, t, pos, c: lm_lib.decode_step(p, t, pos, c, cfg))
tok = jnp.argmax(logits, -1).astype(jnp.int32)
toks = [tok]
t0 = time.time()
for i in range(GEN - 1):
    logits, caches = decode(params, tok, jnp.asarray(16 + i, jnp.int32), caches)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks.append(tok)
jax.block_until_ready(tok)
dt = time.time() - t0
print(f"served batch={B}: {GEN-1} decode steps in {dt*1e3:.0f} ms "
      f"({(GEN-1)*B/dt:.0f} tok/s on CPU)")
print(f"sample continuation: {jnp.stack(toks,1)[0].tolist()}")

# the binarized matmuls are exactly the surface TacitMap accelerates:
n_bin = sum(
    1 for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]
    if leaf.ndim >= 2 and "blocks" in str(path)
)
print(f"{n_bin} hidden projection tensors run as XNOR+popcount "
      f"(deployable on EinsteinBarrier or the packed TPU kernel)")

# -- telemetry: the same model through compile() with tracing on -------------
# obs.session() enables the PR 8 telemetry subsystem for the block:
# compile-stage spans, fenced per-tick decode spans, scheduler lifecycle
# events and serving metrics — all off (one None check) outside it.
from repro import compiler as compiler_lib, obs
from repro.serving import Request

with obs.session() as tel:
    compiled = compiler_lib.compile(
        cfg, params, compiler_lib.HardwareTarget(engine="wdm", group_size=4)
    )
    se = compiled.serve(max_batch=4, max_len=16 + GEN)
    for rid in range(4):
        se.submit(Request(rid=rid, prompt=prompts[rid][:8], max_new_tokens=GEN))
    se.drain()
    report = obs.format_report(obs.crosscheck_serving(se))

print("\n== metrics snapshot (Prometheus text exposition) ==")
print(tel.metrics.render())
print("== measured vs modeled decode-tick pricing ==")
print(report)
n = tel.tracer.export_chrome("/tmp/serve_bnn_lm_trace.json")
print(f"wrote {n} trace records -> /tmp/serve_bnn_lm_trace.json "
      f"(load in chrome://tracing or Perfetto)")
