"""End-to-end driver: train a BNN (the paper's workload class) with
latent-weight STE training, then deploy it through all three execution
engines and the cost model — training -> mapping -> accelerator
latency/energy, the full pipeline of the paper.

    PYTHONPATH=src python examples/train_bnn.py [--steps 300]

The model is the MLP-S class (784-500-250-10) from the paper's MlBench
suite, trained on the class-conditional synthetic MNIST stand-in from
repro.data (offline container — no dataset downloads), hidden layers
binarized with straight-through estimators, first/last layers
high-precision (§II-B of the paper).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import model as bnn_model
from repro.core.networks import MLP_S
from repro.data import bnn_image_batch
from repro.optim import OptConfig, adamw_init, adamw_update


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = bnn_model.MLPConfig(dims=(784, 500, 250, 10))
    params = bnn_model.init_mlp(jax.random.key(0), cfg)
    opt_cfg = OptConfig(weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            logits = bnn_model.mlp_forward_train(p, x, cfg)
            onehot = jax.nn.one_hot(y, 10)
            return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(grads, params, opt, args.lr, opt_cfg)
        return params, opt, loss

    t0 = time.time()
    for i in range(args.steps):
        x, y = bnn_image_batch(args.batch, shape=(28, 28, 1), step=i)
        params, opt, loss = step(params, opt, x.reshape(args.batch, -1), y)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f}")
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s")

    # -- eval through every registered execution engine ---------------------
    from repro.core import engine as engine_lib

    x, y = bnn_image_batch(512, shape=(28, 28, 1), step=10_000)
    x = x.reshape(512, -1)
    for engine in engine_lib.list_engines():
        if engine == "custbinarymap":
            continue  # row-serial sim materializes (B, n, m) — demo stays lean
        logits = bnn_model.mlp_forward_infer(params, x, cfg, engine=engine)
        acc = float(jnp.mean((jnp.argmax(logits, -1) == y)))
        print(f"engine={engine:13s} accuracy {acc:.3f}")

    # -- what the accelerator buys you (the paper's Fig. 7/8 for this net) --
    r = cm.evaluate_all(MLP_S)
    base = r["Baseline-ePCM"]
    print("\nprojected deployment (per image, batch-16 stream):")
    for name, v in r.items():
        sp = base["latency_s"] / v["latency_s"]
        print(f"  {name:16s} {v['latency_s']*1e6:9.2f} us  {v['energy_j']*1e9:9.1f} nJ  "
              f"({sp:7.1f}x vs Baseline-ePCM)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
